//! Fixed-capacity per-point neighbour sets, stored as one contiguous
//! table for all points.
//!
//! Each point owns a slice of `k` slots `(dist, idx)` organised as a
//! binary max-heap on `dist` (worst neighbour at the root), giving O(1)
//! "should I even consider this candidate?" checks and O(log k)
//! replacement. Membership tests are linear scans — `k` ≤ 64 in
//! practice, so a scan over one or two cache lines beats any hash
//! structure.

/// Sentinel index for an empty slot.
pub const EMPTY: u32 = u32::MAX;

/// A contiguous (n × k) neighbour table.
#[derive(Clone, Debug)]
pub struct NeighborTable {
    k: usize,
    n: usize,
    /// Heap-ordered distances, n*k, f32::INFINITY for empty slots.
    dists: Vec<f32>,
    /// Neighbour indices aligned with `dists`, EMPTY for empty slots.
    idxs: Vec<u32>,
    /// Number of filled slots per point.
    lens: Vec<u32>,
}

impl NeighborTable {
    pub fn new(n: usize, k: usize) -> Self {
        assert!(k >= 1);
        NeighborTable {
            k,
            n,
            dists: vec![f32::INFINITY; n * k],
            idxs: vec![EMPTY; n * k],
            lens: vec![0; n],
        }
    }

    #[inline(always)]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline(always)]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline(always)]
    pub fn len(&self, i: usize) -> usize {
        self.lens[i] as usize
    }

    pub fn is_empty(&self, i: usize) -> bool {
        self.lens[i] == 0
    }

    /// The current worst (largest) distance for point `i`, or +inf if the
    /// set is not yet full — matching the "accept anything" semantics.
    #[inline(always)]
    pub fn worst_dist(&self, i: usize) -> f32 {
        if self.len(i) < self.k {
            f32::INFINITY
        } else {
            self.dists[i * self.k]
        }
    }

    /// Neighbour indices of point `i` (filled slots only, heap order).
    #[inline(always)]
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.idxs[i * self.k..i * self.k + self.len(i)]
    }

    /// (idx, dist) pairs for point `i` in heap order.
    pub fn entries(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let base = i * self.k;
        let len = self.len(i);
        (0..len).map(move |s| (self.idxs[base + s], self.dists[base + s]))
    }

    /// Neighbour indices of `i` sorted by ascending distance.
    pub fn sorted_neighbors(&self, i: usize) -> Vec<u32> {
        let mut v: Vec<(f32, u32)> = self.entries(i).map(|(j, d)| (d, j)).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        v.into_iter().map(|(_, j)| j).collect()
    }

    /// Linear membership scan.
    #[inline(always)]
    pub fn contains(&self, i: usize, j: u32) -> bool {
        let base = i * self.k;
        let len = self.len(i);
        self.idxs[base..base + len].contains(&j)
    }

    /// Try to insert neighbour `j` at distance `d` into point `i`'s set.
    /// Returns true iff the set changed. Rejects self-links, duplicates,
    /// and candidates no better than the current worst.
    #[inline]
    pub fn insert(&mut self, i: usize, j: u32, d: f32) -> bool {
        debug_assert!(j != EMPTY);
        if j as usize == i || !d.is_finite() {
            return false;
        }
        let base = i * self.k;
        let len = self.len(i);
        if len == self.k && d >= self.dists[base] {
            return false; // not better than the worst
        }
        if self.idxs[base..base + len].contains(&j) {
            return false;
        }
        if len < self.k {
            // Append then sift up.
            let mut slot = len;
            self.dists[base + slot] = d;
            self.idxs[base + slot] = j;
            self.lens[i] += 1;
            // Sift up (max-heap).
            while slot > 0 {
                let parent = (slot - 1) / 2;
                if self.dists[base + parent] < self.dists[base + slot] {
                    self.dists.swap(base + parent, base + slot);
                    self.idxs.swap(base + parent, base + slot);
                    slot = parent;
                } else {
                    break;
                }
            }
        } else {
            // Replace root then sift down.
            self.dists[base] = d;
            self.idxs[base] = j;
            let mut slot = 0;
            loop {
                let l = 2 * slot + 1;
                let r = 2 * slot + 2;
                let mut largest = slot;
                if l < self.k && self.dists[base + l] > self.dists[base + largest] {
                    largest = l;
                }
                if r < self.k && self.dists[base + r] > self.dists[base + largest] {
                    largest = r;
                }
                if largest == slot {
                    break;
                }
                self.dists.swap(base + slot, base + largest);
                self.idxs.swap(base + slot, base + largest);
                slot = largest;
            }
        }
        true
    }

    /// Recompute all stored distances for point `i` with a new metric /
    /// moved coordinates, re-heapifying. Used when LD points move or the
    /// HD metric changes on the fly.
    pub fn rescore(&mut self, i: usize, mut dist_of: impl FnMut(u32) -> f32) {
        let base = i * self.k;
        let len = self.len(i);
        for s in 0..len {
            self.dists[base + s] = dist_of(self.idxs[base + s]);
        }
        // Heapify the region.
        for s in (0..len / 2).rev() {
            let mut slot = s;
            loop {
                let l = 2 * slot + 1;
                let r = 2 * slot + 2;
                let mut largest = slot;
                if l < len && self.dists[base + l] > self.dists[base + largest] {
                    largest = l;
                }
                if r < len && self.dists[base + r] > self.dists[base + largest] {
                    largest = r;
                }
                if largest == slot {
                    break;
                }
                self.dists.swap(base + slot, base + largest);
                self.idxs.swap(base + slot, base + largest);
                slot = largest;
            }
        }
    }

    /// Drop every stored reference to point `gone`, and rewrite
    /// references to `moved` (the old last index that swapped into
    /// `gone`'s slot) if provided. Supports dynamic point removal.
    pub fn purge(&mut self, gone: u32, moved: Option<u32>) {
        for i in 0..self.n {
            let base = i * self.k;
            let mut len = self.len(i);
            let mut s = 0;
            while s < len {
                let idx = self.idxs[base + s];
                if idx == gone {
                    // Remove slot s: move last slot in, shrink, re-heapify later.
                    len -= 1;
                    self.dists[base + s] = self.dists[base + len];
                    self.idxs[base + s] = self.idxs[base + len];
                    self.dists[base + len] = f32::INFINITY;
                    self.idxs[base + len] = EMPTY;
                    continue; // re-examine slot s
                }
                if Some(idx) == moved {
                    self.idxs[base + s] = gone; // moved point now lives at `gone`
                }
                s += 1;
            }
            self.lens[i] = len as u32;
            // Restore heap property after removals.
            if len > 1 {
                let d = &mut self.dists[base..base + len];
                let x = &mut self.idxs[base..base + len];
                heapify(d, x);
            }
        }
    }

    /// Add one empty row (dynamic insertion).
    pub fn push_point(&mut self) {
        self.n += 1;
        self.dists.extend(std::iter::repeat(f32::INFINITY).take(self.k));
        self.idxs.extend(std::iter::repeat(EMPTY).take(self.k));
        self.lens.push(0);
    }

    /// Remove the last row (after swap-remove bookkeeping).
    pub fn pop_point(&mut self) {
        assert!(self.n > 0);
        self.n -= 1;
        self.dists.truncate(self.n * self.k);
        self.idxs.truncate(self.n * self.k);
        self.lens.pop();
    }

    /// Clear point `i`'s set (e.g. after it moved to new coordinates).
    pub fn clear_point(&mut self, i: usize) {
        let base = i * self.k;
        for s in 0..self.k {
            self.dists[base + s] = f32::INFINITY;
            self.idxs[base + s] = EMPTY;
        }
        self.lens[i] = 0;
    }

    /// Swap the contents of two rows (dynamic removal bookkeeping).
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for s in 0..self.k {
            self.dists.swap(a * self.k + s, b * self.k + s);
            self.idxs.swap(a * self.k + s, b * self.k + s);
        }
        self.lens.swap(a, b);
    }
}

fn heapify(dists: &mut [f32], idxs: &mut [u32]) {
    let len = dists.len();
    for s in (0..len / 2).rev() {
        let mut slot = s;
        loop {
            let l = 2 * slot + 1;
            let r = 2 * slot + 2;
            let mut largest = slot;
            if l < len && dists[l] > dists[largest] {
                largest = l;
            }
            if r < len && dists[r] > dists[largest] {
                largest = r;
            }
            if largest == slot {
                break;
            }
            dists.swap(slot, largest);
            idxs.swap(slot, largest);
            slot = largest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    fn heap_ok(t: &NeighborTable, i: usize) -> bool {
        let base = i * t.k;
        let len = t.len(i);
        for s in 0..len {
            let l = 2 * s + 1;
            let r = 2 * s + 2;
            if l < len && t.dists[base + l] > t.dists[base + s] {
                return false;
            }
            if r < len && t.dists[base + r] > t.dists[base + s] {
                return false;
            }
        }
        true
    }

    #[test]
    fn insert_keeps_best_k() {
        let mut t = NeighborTable::new(1, 3);
        assert!(t.insert(0, 10, 5.0));
        assert!(t.insert(0, 11, 3.0));
        assert!(t.insert(0, 12, 4.0));
        // Set is full with worst 5.0; 6.0 must be rejected, 1.0 accepted.
        assert!(!t.insert(0, 13, 6.0));
        assert!(t.insert(0, 14, 1.0));
        let mut sorted = t.sorted_neighbors(0);
        sorted.sort_unstable();
        assert_eq!(sorted, vec![11, 12, 14]);
        assert!((t.worst_dist(0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_self_and_duplicates() {
        let mut t = NeighborTable::new(2, 4);
        assert!(!t.insert(1, 1, 0.0)); // self
        assert!(t.insert(1, 0, 1.0));
        assert!(!t.insert(1, 0, 0.5)); // duplicate (even if closer)
        assert_eq!(t.len(1), 1);
    }

    #[test]
    fn property_heap_and_topk_match_naive() {
        pt::check("neighbor-table-topk", 48, |rng, _| {
            let k = rng.range_usize(1, 9);
            let m = rng.range_usize(1, 60);
            let mut t = NeighborTable::new(1, k);
            let mut naive: Vec<(f32, u32)> = Vec::new();
            // Distinct candidate ids (duplicate-handling is covered by
            // `rejects_self_and_duplicates`; here we verify top-k).
            let mut ids: Vec<usize> = (1..=m).collect();
            rng.shuffle(&mut ids);
            for j in ids {
                let d = rng.f32() * 10.0;
                t.insert(0, j as u32, d);
                naive.push((d, j as u32));
            }
            crate::prop_assert!(heap_ok(&t, 0), "heap violated");
            naive.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            // NOTE: duplicates in the naive list keep the FIRST distance seen,
            // matching table semantics (duplicates rejected).
            let expect: std::collections::HashSet<u32> =
                naive.iter().take(k).map(|&(_, j)| j).collect();
            let got: std::collections::HashSet<u32> =
                t.neighbors(0).iter().copied().collect();
            // Ties at the cut can differ; compare distances instead.
            let worst_expect = naive.get(k.saturating_sub(1)).map(|e| e.0);
            if let Some(we) = worst_expect {
                let mut got_d: Vec<f32> = t.entries(0).map(|(_, d)| d).collect();
                got_d.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let naive_d: Vec<f32> =
                    naive.iter().take(k).map(|&(d, _)| d).collect();
                for (a, b) in got_d.iter().zip(&naive_d) {
                    crate::prop_assert!((a - b).abs() < 1e-6, "top-k dists differ");
                }
                let _ = we;
            } else {
                crate::prop_assert!(expect == got, "sets differ under k");
            }
            Ok(())
        });
    }

    /// The probe/metrics stack leans on three table invariants holding
    /// at ANY insertion order: ranked lists come out sorted by distance,
    /// and are free of duplicates and self-links. The kept top-k
    /// *distance multiset* must also be insertion-order invariant
    /// (candidate ids may differ under exact distance ties at the cut).
    #[test]
    fn property_insert_order_sorted_dupfree_selffree() {
        pt::check("neighbor-insert-order", 48, |rng, _| {
            let k = rng.range_usize(1, 9);
            let m = rng.range_usize(1, 30);
            // Candidate pool over ids 0..=m with one fixed distance per
            // id (0 is the owner, i.e. a self-link), plus duplicate
            // submissions of existing candidates.
            let mut pool: Vec<(u32, f32)> =
                (0..=m as u32).map(|j| (j, rng.f32() * 10.0)).collect();
            for _ in 0..rng.below(m + 1) {
                let dup = pool[rng.below(m + 1)];
                pool.push(dup);
            }
            let build = |order: &[(u32, f32)]| {
                let mut t = NeighborTable::new(1, k);
                for &(j, d) in order {
                    t.insert(0, j, d);
                }
                t
            };
            let t1 = build(&pool);
            let mut shuffled = pool.clone();
            rng.shuffle(&mut shuffled);
            let t2 = build(&shuffled);
            for t in [&t1, &t2] {
                crate::prop_assert!(heap_ok(t, 0), "heap violated");
                let nb = t.sorted_neighbors(0);
                crate::prop_assert!(!nb.contains(&0), "self-link kept");
                let distinct: std::collections::HashSet<u32> = nb.iter().copied().collect();
                crate::prop_assert!(distinct.len() == nb.len(), "duplicate kept");
                // sorted_neighbors is ascending in stored distance.
                let dist_of = |j: u32| t.entries(0).find(|&(jj, _)| jj == j).unwrap().1;
                let mut prev = f32::NEG_INFINITY;
                for &j in &nb {
                    let d = dist_of(j);
                    crate::prop_assert!(d >= prev, "sorted_neighbors not ascending");
                    prev = d;
                }
            }
            let sorted_dists = |t: &NeighborTable| {
                let mut v: Vec<f32> = t.entries(0).map(|(_, d)| d).collect();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v
            };
            crate::prop_assert!(
                sorted_dists(&t1) == sorted_dists(&t2),
                "top-k distances depend on insertion order"
            );
            Ok(())
        });
    }

    #[test]
    fn rescore_reheapifies() {
        let mut t = NeighborTable::new(1, 4);
        for (j, d) in [(1u32, 1.0f32), (2, 2.0), (3, 3.0), (4, 4.0)] {
            t.insert(0, j, d);
        }
        // Invert the metric: j -> 10 - old d
        t.rescore(0, |j| 10.0 - j as f32);
        assert!(heap_ok(&t, 0));
        assert_eq!(t.worst_dist(0), 9.0); // j=1 now worst
    }

    #[test]
    fn purge_removes_and_renames() {
        let mut t = NeighborTable::new(3, 3);
        t.insert(0, 2, 1.0);
        t.insert(0, 5, 2.0);
        t.insert(1, 5, 0.5);
        t.insert(2, 1, 0.1);
        // Point 2 removed; point 5 (old last) moved into slot 2.
        t.purge(2, Some(5));
        assert!(!t.contains(0, 5)); // renamed to 2
        assert!(t.contains(0, 2)); // the renamed one
        assert_eq!(t.len(0), 1);
        assert!(t.contains(1, 2));
        assert!(t.contains(2, 1)); // untouched entry survives
        assert!(heap_ok(&t, 0) && heap_ok(&t, 1) && heap_ok(&t, 2));
    }

    #[test]
    fn purge_row_with_both_gone_and_moved() {
        // swap-remove of point 2 with old-last point 4 taking its index:
        // a single row holding BOTH must drop the `gone` entry and
        // rename the `moved` entry in the same sweep.
        let mut t = NeighborTable::new(5, 4);
        t.insert(0, 2, 1.0); // gone
        t.insert(0, 4, 2.0); // moved → must become 2
        t.insert(0, 1, 3.0); // untouched
        t.purge(2, Some(4));
        assert_eq!(t.len(0), 2);
        assert!(!t.contains(0, 4), "moved index must be renamed");
        assert!(t.contains(0, 2), "renamed entry must survive");
        assert!(t.contains(0, 1), "unrelated entry must survive");
        // Distances follow their ids through the rename.
        let d2 = t.entries(0).find(|&(j, _)| j == 2).unwrap().1;
        assert!((d2 - 2.0).abs() < 1e-9, "renamed entry kept the wrong dist: {d2}");
        assert!(heap_ok(&t, 0));

        // The removal's backfill slot itself holding `moved`: removing
        // the heap root pulls the last slot forward, and the re-examined
        // slot must still get renamed (regression for the `continue`
        // path).
        let mut t = NeighborTable::new(5, 4);
        t.insert(0, 2, 5.0); // gone at the root (worst dist)
        t.insert(0, 1, 1.0);
        t.insert(0, 4, 2.0); // moved, sits in the backfill slot
        t.purge(2, Some(4));
        assert_eq!(t.len(0), 2);
        assert!(t.contains(0, 2) && t.contains(0, 1) && !t.contains(0, 4));
        assert!(heap_ok(&t, 0));
    }

    #[test]
    fn dynamic_rows() {
        let mut t = NeighborTable::new(2, 2);
        t.push_point();
        assert_eq!(t.n(), 3);
        t.insert(2, 0, 1.0);
        assert_eq!(t.len(2), 1);
        t.swap_rows(0, 2);
        assert_eq!(t.len(0), 1);
        t.pop_point();
        assert_eq!(t.n(), 2);
    }

    #[test]
    fn clear_point_resets() {
        let mut t = NeighborTable::new(1, 2);
        t.insert(0, 1, 1.0);
        t.clear_point(0);
        assert_eq!(t.len(0), 0);
        assert_eq!(t.worst_dist(0), f32::INFINITY);
    }
}
