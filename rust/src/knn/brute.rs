//! Exact K-nearest neighbours by full scan — O(N²·d).
//!
//! Used as ground truth for the R_NX / recall metrics and for small-N
//! reference runs (the paper computes exact sets "for the purpose of
//! this validation experiment", Fig. 4).

use super::neighbor_set::NeighborTable;
use crate::data::matrix::{sqdist, Matrix};

/// Exact KNN table of `x` under squared-Euclidean distance.
pub fn brute_knn(x: &Matrix, k: usize) -> NeighborTable {
    let n = x.n();
    let mut t = NeighborTable::new(n, k);
    for i in 0..n {
        let xi = x.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let d = sqdist(xi, x.row(j));
            // worst_dist check is inside insert; a cheap pre-check saves
            // the membership scan for clearly-too-far candidates.
            if d < t.worst_dist(i) {
                t.insert(i, j as u32, d);
            }
        }
    }
    t
}

/// Exact neighbours of a single query row against the whole matrix,
/// returned sorted ascending (used by dynamic-insertion seeding and the
/// 1-NN classifier).
pub fn knn_of_query(x: &Matrix, query: &[f32], k: usize, skip: Option<usize>) -> Vec<(u32, f32)> {
    let mut t = NeighborTable::new(1, k);
    for j in 0..x.n() {
        if Some(j) == skip {
            continue;
        }
        let d = sqdist(query, x.row(j));
        if d < t.worst_dist(0) {
            // Shift ids by 1: the table owner has row index 0 and would
            // otherwise reject data row 0 as a self-link.
            t.insert(0, (j + 1) as u32, d);
        }
    }
    let mut out: Vec<(u32, f32)> = t.entries(0).map(|(j, d)| (j - 1, d)).collect();
    out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    #[test]
    fn brute_matches_naive_sort() {
        pt::check("brute-vs-sort", 24, |rng, _| {
            let n = rng.range_usize(5, 40);
            let d = rng.range_usize(1, 6);
            let k = rng.range_usize(1, n.min(8));
            let x = Matrix::from_vec(pt::gauss_mat(rng, n, d, 2.0), n, d).unwrap();
            let t = brute_knn(&x, k);
            for i in 0..n {
                let mut all: Vec<(f32, usize)> = (0..n)
                    .filter(|&j| j != i)
                    .map(|j| (x.sqdist(i, j), j))
                    .collect();
                all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                let mut expect_d: Vec<f32> = all.iter().take(k).map(|e| e.0).collect();
                let mut got_d: Vec<f32> = t.entries(i).map(|(_, dd)| dd).collect();
                expect_d.sort_by(|a, b| a.partial_cmp(b).unwrap());
                got_d.sort_by(|a, b| a.partial_cmp(b).unwrap());
                crate::prop_assert!(expect_d.len() == got_d.len(), "len mismatch at {i}");
                for (e, g) in expect_d.iter().zip(&got_d) {
                    crate::prop_assert!((e - g).abs() < 1e-6, "dist mismatch at {i}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn query_knn_sorted_and_skips() {
        let x = Matrix::from_vec(vec![0.0, 1.0, 2.0, 3.0], 4, 1).unwrap();
        let res = knn_of_query(&x, &[1.1], 2, Some(1));
        assert_eq!(res.len(), 2);
        // |1.1-2| = 0.9 < |1.1-0| = 1.1 once row 1 is skipped.
        assert_eq!(res[0].0, 2);
        assert_eq!(res[1].0, 0);
        assert!(res[0].1 <= res[1].1);
    }

    #[test]
    fn query_knn_can_return_row_zero() {
        // Regression: row 0 must be retrievable (the table's internal
        // owner index used to shadow it).
        let x = Matrix::from_vec(vec![5.0, 100.0, 200.0], 3, 1).unwrap();
        let res = knn_of_query(&x, &[5.1], 1, None);
        assert_eq!(res[0].0, 0);
    }
}
