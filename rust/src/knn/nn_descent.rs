//! Nearest-neighbour descent (Dong, Moses, Li — WWW'11 [1]).
//!
//! The baseline the paper compares its iterative finder against
//! (Figs 7/8). Classic formulation: initialise each point's K-NN set
//! randomly; each round, for every point, take its *new* neighbours
//! (those inserted since last round, subsampled at rate ρ) and its *old*
//! neighbours, plus the reverse sets, and test all new×(new ∪ old)
//! pairs. Converges when an round improves fewer than δ·N·K entries —
//! greedy, hence prone to the "Disjointed blobs" local minimum the
//! paper exploits in Fig. 7.

use super::neighbor_set::NeighborTable;
use crate::config::KnnConfig;
use crate::data::matrix::{sqdist, Matrix};
use crate::util::Rng;

/// Outcome of a run: the table plus per-round update counts (for the
/// convergence plots).
pub struct NnDescentResult {
    pub table: NeighborTable,
    pub updates_per_round: Vec<usize>,
    /// Total number of distance evaluations performed.
    pub dist_evals: u64,
}

/// Run NN-descent to convergence (or `cfg.max_rounds`).
pub fn nn_descent(x: &Matrix, cfg: &KnnConfig) -> NnDescentResult {
    let n = x.n();
    let k = cfg.k.min(n.saturating_sub(1)).max(1);
    let mut rng = Rng::new(cfg.seed);
    let mut table = NeighborTable::new(n, k);
    // `new` flag per (point, slot) is tracked via a parallel set of
    // recently-inserted neighbour ids per point.
    let mut fresh: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut dist_evals: u64 = 0;

    // Random initialisation.
    for i in 0..n {
        while table.len(i) < k {
            let j = rng.below(n);
            if j != i {
                let d = sqdist(x.row(i), x.row(j));
                dist_evals += 1;
                if table.insert(i, j as u32, d) {
                    fresh[i].push(j as u32);
                }
            }
        }
    }

    let mut updates_per_round = Vec::new();
    for _round in 0..cfg.max_rounds {
        // Build sampled new/old and reverse-new/old lists.
        let mut new_list: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_list: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            let fresh_set: &Vec<u32> = &fresh[i];
            for j in table.neighbors(i) {
                let is_new = fresh_set.contains(j);
                if is_new {
                    if rng.chance(cfg.rho) {
                        new_list[i].push(*j);
                    }
                } else {
                    old_list[i].push(*j);
                }
            }
        }
        // Reverse edges (sampled like the forward new ones).
        let mut rev_new: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut rev_old: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            for &j in &new_list[i] {
                rev_new[j as usize].push(i as u32);
            }
            for &j in &old_list[i] {
                rev_old[j as usize].push(i as u32);
            }
        }
        let mut next_fresh: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut updates = 0usize;
        let try_pair = |a: u32,
                            b: u32,
                            table: &mut NeighborTable,
                            next_fresh: &mut Vec<Vec<u32>>,
                            dist_evals: &mut u64|
         -> usize {
            if a == b {
                return 0;
            }
            let d = sqdist(x.row(a as usize), x.row(b as usize));
            *dist_evals += 1;
            let mut u = 0;
            if table.insert(a as usize, b, d) {
                next_fresh[a as usize].push(b);
                u += 1;
            }
            if table.insert(b as usize, a, d) {
                next_fresh[b as usize].push(a);
                u += 1;
            }
            u
        };
        for i in 0..n {
            // union new: forward + sampled reverse
            let mut nn: Vec<u32> = new_list[i].clone();
            for &r in &rev_new[i] {
                if rng.chance(cfg.rho) {
                    nn.push(r);
                }
            }
            let mut oo: Vec<u32> = old_list[i].clone();
            for &r in &rev_old[i] {
                if rng.chance(cfg.rho) {
                    oo.push(r);
                }
            }
            // new × new
            for ai in 0..nn.len() {
                for bi in (ai + 1)..nn.len() {
                    updates += try_pair(nn[ai], nn[bi], &mut table, &mut next_fresh, &mut dist_evals);
                }
                // new × old
                for &b in &oo {
                    updates += try_pair(nn[ai], b, &mut table, &mut next_fresh, &mut dist_evals);
                }
            }
        }
        updates_per_round.push(updates);
        fresh = next_fresh;
        if (updates as f64) < cfg.delta * (n * k) as f64 {
            break;
        }
    }
    NnDescentResult { table, updates_per_round, dist_evals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;

    /// Mean recall of `approx` vs exact `truth` at their common k.
    pub fn recall(truth: &NeighborTable, approx: &NeighborTable) -> f64 {
        let n = truth.n();
        let mut hit = 0usize;
        let mut total = 0usize;
        for i in 0..n {
            for j in truth.neighbors(i) {
                total += 1;
                if approx.contains(i, *j) {
                    hit += 1;
                }
            }
        }
        hit as f64 / total.max(1) as f64
    }

    #[test]
    fn converges_on_overlapping_blobs() {
        let ds = datasets::blobs_overlapping(600, 8, 1);
        let cfg = KnnConfig { k: 10, rho: 0.8, ..KnnConfig::default() };
        let res = nn_descent(&ds.x, &cfg);
        let truth = brute_knn(&ds.x, 10);
        let r = recall(&truth, &res.table);
        assert!(r > 0.88, "NN-descent recall too low: {r}");
        // Must beat a naive random table by a wide margin and use far
        // fewer evals than brute force.
        assert!(res.dist_evals < (600u64 * 600) , "evals {} not sub-quadratic", res.dist_evals);
    }

    #[test]
    fn update_counts_decrease() {
        let ds = datasets::blobs(400, 8, 4, 0.5, 10.0, 2);
        let res = nn_descent(&ds.x, &KnnConfig { k: 8, ..KnnConfig::default() });
        let u = &res.updates_per_round;
        assert!(u.len() >= 2);
        assert!(
            *u.last().unwrap() < u[0] / 2,
            "updates did not decay: {u:?}"
        );
    }

    #[test]
    fn struggles_on_disjoint_blobs() {
        // The Fig. 7 premise: tight isolated clusters trap the greedy
        // search. Recall should be visibly below the overlapping case.
        let ds = datasets::blobs_disjointed(120, 8, 16, 3);
        let cfg = KnnConfig { k: 6, rho: 0.5, max_rounds: 12, ..KnnConfig::default() };
        let res = nn_descent(&ds.x, &cfg);
        let truth = brute_knn(&ds.x, 6);
        let r = recall(&truth, &res.table);
        // Not asserting failure — just that the scenario is harder than
        // the near-perfect overlapping case (sanity for the Fig. 7 bench).
        assert!(r < 0.999, "disjoint case unexpectedly trivial: {r}");
    }

    #[test]
    fn k_clamped_for_tiny_n() {
        let ds = datasets::blobs(5, 3, 1, 0.1, 1.0, 4);
        let res = nn_descent(&ds.x, &KnnConfig { k: 32, ..KnnConfig::default() });
        assert_eq!(res.table.k(), 4);
    }
}
