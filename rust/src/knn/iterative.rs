//! The paper's iterative *cross-space* KNN refinement.
//!
//! Twin estimated neighbour tables — `hd` (under the data metric) and
//! `ld` (under the embedding metric) — are refined a little at every
//! engine iteration. Candidate generation is the novelty: a candidate
//! destined for the HD set of point *i* can come from
//!
//! 1. HD neighbours of *i*'s HD neighbours (NN-descent style),
//! 2. *i*'s **LD** neighbours (cross-space route),
//! 3. LD neighbours of *i*'s LD neighbours (cross-space NN route),
//! 4. uniform random points (the escape hatch that makes the scheme
//!    "less prone to local minima than nearest-neighbour descent").
//!
//! and symmetrically for the LD set. Because the embedding improves as
//! the HD sets improve and vice versa, the two refinements form the
//! positive feedback loop of Fig. 4.
//!
//! Candidate *generation* (index juggling) is separated from candidate
//! *scoring* (distance computation) so the coordinator can score a whole
//! tile of candidates in one AOT-compiled XLA call (the `sqdist_*`
//! artifact) instead of point by point.

use super::neighbor_set::NeighborTable;
use crate::data::matrix::{sqdist, Matrix};
use crate::util::Rng;

/// The twin tables plus refresh bookkeeping.
#[derive(Clone, Debug)]
pub struct IterativeKnn {
    /// Estimated HD neighbour sets (size k_hd).
    pub hd: NeighborTable,
    /// Estimated LD neighbour sets (size k_ld).
    pub ld: NeighborTable,
    /// Per-point flag: discovered a new HD neighbour since last σ
    /// recalibration sweep (paper §3).
    pub hd_dirty: Vec<bool>,
}

/// Where candidates may come from (used by the ablation bench to switch
/// the cross-space routes off and recover plain NN-descent behaviour).
#[derive(Clone, Copy, Debug)]
pub struct CandidateRoutes {
    pub same_space: bool,
    pub cross_space: bool,
    pub random: bool,
}

impl Default for CandidateRoutes {
    fn default() -> Self {
        CandidateRoutes { same_space: true, cross_space: true, random: true }
    }
}

impl IterativeKnn {
    /// Fresh state with randomly-seeded tables.
    pub fn new(n: usize, k_hd: usize, k_ld: usize) -> Self {
        IterativeKnn {
            hd: NeighborTable::new(n, k_hd),
            ld: NeighborTable::new(n, k_ld),
            hd_dirty: vec![true; n],
        }
    }

    pub fn n(&self) -> usize {
        self.hd.n()
    }

    /// Seed both tables with `seeds` random links per point, scored with
    /// the true metrics (one-off O(N·seeds·d)).
    pub fn seed_random(&mut self, x: &Matrix, y: &Matrix, rng: &mut Rng) {
        let n = self.n();
        if n < 2 {
            return;
        }
        let seeds_hd = self.hd.k().min(n - 1);
        let seeds_ld = self.ld.k().min(n - 1);
        for i in 0..n {
            for _ in 0..seeds_hd {
                let j = rng.below(n);
                if j != i {
                    self.hd.insert(i, j as u32, sqdist(x.row(i), x.row(j)));
                }
            }
            for _ in 0..seeds_ld {
                let j = rng.below(n);
                if j != i {
                    self.ld.insert(i, j as u32, sqdist(y.row(i), y.row(j)));
                }
            }
        }
    }

    /// One HD refinement sweep over all points (native scoring).
    /// Returns the number of points that received ≥1 new neighbour —
    /// the `N_new` of the paper's refresh-probability heuristic.
    pub fn refine_hd_native(
        &mut self,
        x: &Matrix,
        n_candidates: usize,
        routes: CandidateRoutes,
        rng: &mut Rng,
        scratch: &mut Vec<u32>,
    ) -> usize {
        let n = self.n();
        let mut n_new = 0usize;
        for i in 0..n {
            scratch.clear();
            gen_candidates(i, &self.hd, &self.ld, n, n_candidates, routes, rng, scratch);
            let mut improved = false;
            let xi = x.row(i);
            for &c in scratch.iter() {
                let d = sqdist(xi, x.row(c as usize));
                if self.hd.insert(i, c, d) {
                    improved = true;
                }
                // Symmetric insertion: i may be a good neighbour for c.
                // (Counted via the dirty flag, not n_new, to keep the
                // paper's "points that received new neighbours" per-sweep
                // semantics.)
                if self.hd.insert(c as usize, i as u32, d) {
                    self.hd_dirty[c as usize] = true;
                }
            }
            if improved {
                self.hd_dirty[i] = true;
                n_new += 1;
            }
        }
        n_new
    }

    /// One LD refinement sweep (native scoring). LD coordinates move at
    /// every gradient step, so stored distances are first rescored
    /// against the current embedding before candidates are tested.
    pub fn refine_ld_native(
        &mut self,
        y: &Matrix,
        n_candidates: usize,
        routes: CandidateRoutes,
        rng: &mut Rng,
        scratch: &mut Vec<u32>,
    ) -> usize {
        let n = self.n();
        let mut n_new = 0usize;
        for i in 0..n {
            self.ld.rescore(i, |j| sqdist(y.row(i), y.row(j as usize)));
            scratch.clear();
            // Note the swapped table roles: LD is primary, HD is cross.
            gen_candidates(i, &self.ld, &self.hd, n, n_candidates, routes, rng, scratch);
            let mut improved = false;
            let yi = y.row(i);
            for &c in scratch.iter() {
                let d = sqdist(yi, y.row(c as usize));
                if self.ld.insert(i, c, d) {
                    improved = true;
                }
                if self.ld.insert(c as usize, i as u32, d) {
                    // symmetric improvement
                }
            }
            if improved {
                n_new += 1;
            }
        }
        n_new
    }

    /// Dynamic insertion: append a point (its sets start empty and fill
    /// up over subsequent refinement sweeps — the "no overhead" claim).
    pub fn push_point(&mut self) {
        self.hd.push_point();
        self.ld.push_point();
        self.hd_dirty.push(true);
    }

    /// Dynamic removal bookkeeping for `swap_remove` semantics: point
    /// `gone` disappears; the previously-last point (if different) now
    /// has index `gone`.
    pub fn swap_remove_point(&mut self, gone: usize) {
        let last = self.n() - 1;
        let moved = if gone != last { Some(last as u32) } else { None };
        self.hd.swap_rows(gone, last);
        self.ld.swap_rows(gone, last);
        self.hd_dirty.swap(gone, last);
        self.hd.pop_point();
        self.ld.pop_point();
        self.hd_dirty.pop();
        self.hd.purge(gone as u32, moved);
        self.ld.purge(gone as u32, moved);
    }
}

/// Generate up to `budget` candidate neighbour ids for point `i`.
///
/// `primary` is the table being refined; `other` is the twin table in
/// the opposite space (the cross-pollination source). Candidates are
/// deduplicated against each other and against `i`; they may already be
/// in the table (insert rejects those cheaply).
#[allow(clippy::too_many_arguments)]
pub fn gen_candidates(
    i: usize,
    primary: &NeighborTable,
    other: &NeighborTable,
    n: usize,
    budget: usize,
    routes: CandidateRoutes,
    rng: &mut Rng,
    out: &mut Vec<u32>,
) {
    debug_assert!(out.is_empty());
    if n < 2 {
        return;
    }
    let push = |c: u32, out: &mut Vec<u32>| {
        if c as usize != i && !out.contains(&c) {
            out.push(c);
        }
    };
    // Route 1 — same-space neighbours of neighbours: pick a random
    // neighbour j, then a random neighbour of j.
    if routes.same_space {
        let tries = budget.div_ceil(2);
        for _ in 0..tries {
            let nb = primary.neighbors(i);
            if nb.is_empty() {
                break;
            }
            let j = nb[rng.below(nb.len())] as usize;
            let nb2 = primary.neighbors(j);
            if !nb2.is_empty() {
                push(nb2[rng.below(nb2.len())], out);
            } else {
                push(j as u32, out);
            }
        }
    }
    // Route 2+3 — cross-space: direct twin neighbours and twin
    // neighbours-of-neighbours.
    if routes.cross_space {
        let nb = other.neighbors(i);
        let tries = budget.div_ceil(2);
        for t in 0..tries {
            if nb.is_empty() {
                break;
            }
            let j = nb[rng.below(nb.len())];
            if t % 2 == 0 {
                push(j, out);
            } else {
                let nb2 = other.neighbors(j as usize);
                if !nb2.is_empty() {
                    push(nb2[rng.below(nb2.len())], out);
                } else {
                    push(j, out);
                }
            }
        }
    }
    // Route 4 — uniform random escape hatch.
    if routes.random {
        let tries = (budget / 4).max(1);
        for _ in 0..tries {
            push(rng.below(n) as u32, out);
        }
    }
    out.truncate(budget.max(1));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;

    fn recall(truth: &NeighborTable, approx: &NeighborTable) -> f64 {
        let n = truth.n();
        let (mut hit, mut tot) = (0usize, 0usize);
        for i in 0..n {
            for j in truth.neighbors(i) {
                tot += 1;
                if approx.contains(i, *j) {
                    hit += 1;
                }
            }
        }
        hit as f64 / tot.max(1) as f64
    }

    /// With a *perfect* LD embedding (LD = HD), cross-space candidates
    /// should drive HD recall high quickly — the feedback-loop premise.
    #[test]
    fn converges_with_identity_embedding() {
        let ds = datasets::blobs(500, 8, 5, 0.8, 10.0, 1);
        let mut rng = crate::util::Rng::new(7);
        let mut knn = IterativeKnn::new(500, 10, 10);
        // LD == HD here (the best possible embedding).
        knn.seed_random(&ds.x, &ds.x, &mut rng);
        let mut scratch = Vec::new();
        for _ in 0..40 {
            knn.refine_hd_native(&ds.x, 8, CandidateRoutes::default(), &mut rng, &mut scratch);
            knn.refine_ld_native(&ds.x, 8, CandidateRoutes::default(), &mut rng, &mut scratch);
        }
        let truth = brute_knn(&ds.x, 10);
        let r = recall(&truth, &knn.hd);
        assert!(r > 0.85, "iterative KNN recall {r}");
    }

    /// Random-route-only ablation must converge more slowly than the
    /// full candidate mix (the candidate routes matter).
    #[test]
    fn routes_beat_random_only() {
        let ds = datasets::blobs(400, 8, 4, 0.8, 10.0, 2);
        let truth = brute_knn(&ds.x, 8);
        let run = |routes: CandidateRoutes, seed: u64| {
            let mut rng = crate::util::Rng::new(seed);
            let mut knn = IterativeKnn::new(400, 8, 8);
            knn.seed_random(&ds.x, &ds.x, &mut rng);
            let mut scratch = Vec::new();
            for _ in 0..15 {
                knn.refine_hd_native(&ds.x, 8, routes, &mut rng, &mut scratch);
                knn.refine_ld_native(&ds.x, 8, routes, &mut rng, &mut scratch);
            }
            recall(&truth, &knn.hd)
        };
        let full = run(CandidateRoutes::default(), 3);
        let rand_only =
            run(CandidateRoutes { same_space: false, cross_space: false, random: true }, 3);
        assert!(
            full > rand_only + 0.05,
            "full routes {full} should beat random-only {rand_only}"
        );
    }

    /// Refinement only ever replaces a stored neighbour with a strictly
    /// closer one, so (with table k == truth k and distinct distances)
    /// the hit count against exact ground truth can never drop: an
    /// insert that evicts a true top-k member admits a point that is
    /// itself inside the true top-k radius. This is the invariant the
    /// online quality probe's `knn_recall_hd` trajectory relies on.
    #[test]
    fn property_recall_vs_brute_non_decreasing_over_rounds() {
        use crate::util::proptest as pt;
        pt::check("iterative-recall-monotone", 6, |rng, _| {
            let n = rng.range_usize(120, 250);
            let seed = rng.next_u64();
            let ds = datasets::blobs(n, 6, 3, 0.6, 8.0, seed);
            let k = 8usize;
            let truth = brute_knn(&ds.x, k);
            let mut krng = crate::util::Rng::new(seed ^ 0x51);
            let mut knn = IterativeKnn::new(n, k, k);
            knn.seed_random(&ds.x, &ds.x, &mut krng);
            let hits = |knn: &IterativeKnn| -> usize {
                (0..n)
                    .map(|i| {
                        truth.neighbors(i).iter().filter(|&&j| knn.hd.contains(i, j)).count()
                    })
                    .sum()
            };
            let mut scratch = Vec::new();
            let mut prev = hits(&knn);
            for round in 0..15 {
                knn.refine_hd_native(
                    &ds.x,
                    8,
                    CandidateRoutes::default(),
                    &mut krng,
                    &mut scratch,
                );
                knn.refine_ld_native(
                    &ds.x,
                    8,
                    CandidateRoutes::default(),
                    &mut krng,
                    &mut scratch,
                );
                let h = hits(&knn);
                crate::prop_assert!(
                    h >= prev,
                    "recall dropped at round {round}: {h} < {prev} (n = {n})"
                );
                prev = h;
            }
            let recall = prev as f64 / (n * k) as f64;
            crate::prop_assert!(recall > 0.4, "recall never improved: {recall} (n = {n})");
            Ok(())
        });
    }

    #[test]
    fn gen_candidates_dedups_and_excludes_self() {
        let mut rng = crate::util::Rng::new(5);
        let mut primary = NeighborTable::new(10, 4);
        let mut other = NeighborTable::new(10, 4);
        for j in 1..5u32 {
            primary.insert(0, j, j as f32);
            other.insert(0, j + 4, j as f32);
        }
        let mut out = Vec::new();
        for _ in 0..20 {
            out.clear();
            gen_candidates(0, &primary, &other, 10, 12, CandidateRoutes::default(), &mut rng, &mut out);
            assert!(!out.contains(&0), "self in candidates");
            let set: std::collections::HashSet<_> = out.iter().collect();
            assert_eq!(set.len(), out.len(), "duplicates in candidates");
            assert!(out.len() <= 12);
        }
    }

    #[test]
    fn dirty_flags_set_on_discovery() {
        let ds = datasets::blobs(100, 4, 2, 0.5, 6.0, 4);
        let mut rng = crate::util::Rng::new(9);
        let mut knn = IterativeKnn::new(100, 6, 6);
        knn.seed_random(&ds.x, &ds.x, &mut rng);
        knn.hd_dirty.iter_mut().for_each(|f| *f = false);
        let mut scratch = Vec::new();
        let n_new =
            knn.refine_hd_native(&ds.x, 8, CandidateRoutes::default(), &mut rng, &mut scratch);
        let dirty = knn.hd_dirty.iter().filter(|&&f| f).count();
        assert!(dirty >= n_new, "dirty {dirty} < n_new {n_new}");
        assert!(n_new > 0, "refinement found nothing on a fresh random table");
    }

    #[test]
    fn dynamic_push_and_remove_keep_tables_consistent() {
        let ds = datasets::blobs(60, 4, 2, 0.5, 6.0, 6);
        let mut rng = crate::util::Rng::new(11);
        let mut knn = IterativeKnn::new(60, 5, 5);
        knn.seed_random(&ds.x, &ds.x, &mut rng);
        knn.push_point();
        assert_eq!(knn.n(), 61);
        knn.swap_remove_point(10);
        assert_eq!(knn.n(), 60);
        // No table may reference an out-of-range index.
        for i in 0..knn.n() {
            for &j in knn.hd.neighbors(i) {
                assert!((j as usize) < knn.n(), "stale hd ref {j}");
            }
            for &j in knn.ld.neighbors(i) {
                assert!((j as usize) < knn.n(), "stale ld ref {j}");
            }
        }
    }
}
