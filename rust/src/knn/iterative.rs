//! The paper's iterative *cross-space* KNN refinement — sharded.
//!
//! Twin estimated neighbour tables — `hd` (under the data metric) and
//! `ld` (under the embedding metric) — are refined a little at every
//! engine iteration. Candidate generation is the novelty: a candidate
//! destined for the HD set of point *i* can come from
//!
//! 1. HD neighbours of *i*'s HD neighbours (NN-descent style),
//! 2. *i*'s **LD** neighbours (cross-space route),
//! 3. LD neighbours of *i*'s LD neighbours (cross-space NN route),
//! 4. uniform random points (the escape hatch that makes the scheme
//!    "less prone to local minima than nearest-neighbour descent").
//!
//! and symmetrically for the LD set. Because the embedding improves as
//! the HD sets improve and vice versa, the two refinements form the
//! positive feedback loop of Fig. 4.
//!
//! # Sharding and determinism
//!
//! A refinement sweep used to be the engine's serial Amdahl tail: one
//! sequential [`Rng`](crate::util::Rng) threaded through candidate
//! generation forced the whole sweep onto one core. The sweep is now a
//! multi-pass pipeline over a [`WorkerPool`], **bitwise
//! thread-count-invariant by construction**:
//!
//! 1. **rescore pass** (LD only; sharded) — each worker owns a disjoint
//!    row range ([`NeighborTable::rows_mut`]) and rescores its rows
//!    against the current embedding;
//! 2. **generate + score pass** (sharded, read-only) — candidates for
//!    point `i` come from the counter-based stream
//!    [`StreamRng::at`]`(seed, iter, i, lane)`, so every shard
//!    partition computes identical candidates; scored results land in
//!    per-shard buffers (scoring is where the arithmetic lives — for
//!    the HD sweep it is batched through the engine's
//!    [`ComputeBackend`](crate::engine::ComputeBackend) instead);
//! 3. **apply pass** — primary inserts go in sharded (each row is
//!    owned by exactly one worker), then symmetric inserts run on the
//!    calling thread in fixed *shard-then-point* order — the one order
//!    every thread count reproduces.
//!
//! Candidate *generation* (index juggling) stays separated from
//! candidate *scoring* (distance computation) so the coordinator can
//! score a whole tile of candidates in one AOT-compiled XLA call (the
//! `sqdist_*` artifact) instead of point by point.

use super::neighbor_set::{NeighborTable, RowsMut};
use crate::data::matrix::{sqdist, Matrix};
use crate::runtime::pool::{effective_shards, shard_ranges, split_by_ranges, WorkerPool};
use crate::util::{lane, RandomSource, Rng, StreamRng};
use std::ops::Range;

/// Minimum points per shard for the refinement passes: below this the
/// scoped-thread fork/join costs more than the per-point rescoring +
/// generation + scoring it buys. Purely a wall-clock knob — the shard
/// partition never changes a single output bit.
pub const MIN_REFINE_POINTS_PER_SHARD: usize = 256;

/// Minimum scored pairs per shard for [`score_pairs_native`].
pub const MIN_SCORE_PAIRS_PER_SHARD: usize = 8192;

/// Apply one shard's scored primary candidates (`owners[t]` ascending,
/// grouped) to its row view, invoking `on_improved(owner)` per
/// successful insert. Returns the number of owners that improved — the
/// paper's per-sweep "points that received new neighbours" count.
/// Shared by the LD and HD apply passes so the `N_new` semantics
/// feeding the refresh-probability EWMA can never fork between spaces.
fn apply_primary(
    view: &mut RowsMut<'_>,
    owners: &[u32],
    cands: &[u32],
    dists: &[f32],
    mut on_improved: impl FnMut(u32),
) -> usize {
    let mut new_points = 0usize;
    let mut prev = u32::MAX;
    let mut improved = false;
    for t in 0..owners.len() {
        let i = owners[t];
        if i != prev {
            if improved {
                new_points += 1;
            }
            improved = false;
            prev = i;
        }
        if view.insert(i as usize, cands[t], dists[t]) {
            improved = true;
            on_improved(i);
        }
    }
    if improved {
        new_points += 1;
    }
    new_points
}

/// The twin tables plus refresh bookkeeping.
#[derive(Clone, Debug)]
pub struct IterativeKnn {
    /// Estimated HD neighbour sets (size k_hd).
    pub hd: NeighborTable,
    /// Estimated LD neighbour sets (size k_ld).
    pub ld: NeighborTable,
    /// Per-point flag: discovered a new HD neighbour since last σ
    /// recalibration sweep (paper §3).
    pub hd_dirty: Vec<bool>,
}

/// Where candidates may come from (used by the ablation bench to switch
/// the cross-space routes off and recover plain NN-descent behaviour).
#[derive(Clone, Copy, Debug)]
pub struct CandidateRoutes {
    pub same_space: bool,
    pub cross_space: bool,
    pub random: bool,
}

impl Default for CandidateRoutes {
    fn default() -> Self {
        CandidateRoutes { same_space: true, cross_space: true, random: true }
    }
}

/// Generation-stamped membership scratch for candidate deduplication:
/// one `u32` stamp per point id, reused across points and iterations
/// with **no per-call clearing** — `begin` bumps the generation and a
/// candidate is fresh iff its stamp differs. Replaces the old
/// O(budget²) `Vec::contains` scan in [`gen_candidates`].
#[derive(Clone, Debug, Default)]
pub struct SeenStamp {
    stamp: Vec<u32>,
    gen: u32,
}

impl SeenStamp {
    /// Start a fresh generation covering ids `[0, n)`. O(1) except on
    /// first use per capacity and on `u32` generation wrap-around
    /// (every 2³² calls), where the stamps are re-zeroed.
    pub fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
        }
        self.gen = self.gen.wrapping_add(1);
        if self.gen == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.gen = 1;
        }
    }

    /// Mark `c` seen; returns true iff it was fresh this generation.
    #[inline(always)]
    pub fn mark(&mut self, c: u32) -> bool {
        let s = &mut self.stamp[c as usize];
        if *s == self.gen {
            false
        } else {
            *s = self.gen;
            true
        }
    }
}

/// Per-worker buffers for one refinement shard.
#[derive(Debug, Default)]
struct ShardScratch {
    seen: SeenStamp,
    /// Per-point candidate ids (cleared per point).
    out: Vec<u32>,
    /// Shard-local flattened (owner, candidate[, distance]) triples in
    /// point order.
    owners: Vec<u32>,
    cands: Vec<u32>,
    dists: Vec<f32>,
}

/// Reusable buffers for the sharded refinement passes — allocation-free
/// once warm. One per engine; pass the same instance to every sweep.
#[derive(Debug, Default)]
pub struct RefineScratch {
    shards: Vec<ShardScratch>,
    /// Flat candidate pairs in shard-then-point order (filled by
    /// [`IterativeKnn::gen_hd_candidates`]; the engine scores them
    /// through its backend and hands the distances back to
    /// [`IterativeKnn::apply_hd_scored`]).
    pub(crate) owners: Vec<u32>,
    pub(crate) cands: Vec<u32>,
    /// Native-path scores for the flat pairs (backend paths keep their
    /// own distance buffer).
    pub(crate) dists: Vec<f32>,
    /// Per-shard pair counts into the flat arrays.
    spans: Vec<usize>,
    /// The point ranges of the generating pass (the apply partition).
    ranges: Vec<Range<usize>>,
}

impl RefineScratch {
    /// The flat candidate pairs of the last generation pass.
    pub fn pairs(&self) -> (&[u32], &[u32]) {
        (&self.owners, &self.cands)
    }

    fn ensure_shards(&mut self, count: usize) {
        if self.shards.len() < count {
            self.shards.resize_with(count, ShardScratch::default);
        }
    }
}

impl IterativeKnn {
    /// Fresh state with randomly-seeded tables.
    pub fn new(n: usize, k_hd: usize, k_ld: usize) -> Self {
        IterativeKnn {
            hd: NeighborTable::new(n, k_hd),
            ld: NeighborTable::new(n, k_ld),
            hd_dirty: vec![true; n],
        }
    }

    pub fn n(&self) -> usize {
        self.hd.n()
    }

    /// Seed both tables with `seeds` random links per point, scored with
    /// the true metrics (one-off O(N·seeds·d)).
    pub fn seed_random(&mut self, x: &Matrix, y: &Matrix, rng: &mut Rng) {
        let n = self.n();
        if n < 2 {
            return;
        }
        let seeds_hd = self.hd.k().min(n - 1);
        let seeds_ld = self.ld.k().min(n - 1);
        for i in 0..n {
            for _ in 0..seeds_hd {
                let j = rng.below(n);
                if j != i {
                    self.hd.insert(i, j as u32, sqdist(x.row(i), x.row(j)));
                }
            }
            for _ in 0..seeds_ld {
                let j = rng.below(n);
                if j != i {
                    self.ld.insert(i, j as u32, sqdist(y.row(i), y.row(j)));
                }
            }
        }
    }

    /// One LD refinement sweep, sharded over `pool` with per-point
    /// counter streams (`lane::LD`) — see the module docs for the
    /// three-pass structure. LD coordinates move at every gradient
    /// step, so stored distances are first rescored against the current
    /// embedding. Returns the number of points that received ≥1 new
    /// neighbour — the `N_new` of the paper's refresh-probability
    /// heuristic.
    #[allow(clippy::too_many_arguments)]
    pub fn refine_ld(
        &mut self,
        y: &Matrix,
        n_candidates: usize,
        routes: CandidateRoutes,
        seed: u64,
        iter: u64,
        pool: &WorkerPool,
        min_points_per_shard: usize,
        scratch: &mut RefineScratch,
    ) -> usize {
        let n = self.n();
        if n < 2 {
            return 0;
        }
        let shards = effective_shards(pool, n, min_points_per_shard);
        let ranges = shard_ranges(n, shards);
        // --- pass 1: rescore (sharded, disjoint rows) ------------------
        {
            let tasks: Vec<_> = self
                .ld
                .rows_mut(&ranges)
                .into_iter()
                .map(|mut view| {
                    move || {
                        for i in view.start()..view.start() + view.rows() {
                            view.rescore(i, |j| sqdist(y.row(i), y.row(j as usize)));
                        }
                    }
                })
                .collect();
            pool.run_tasks(tasks);
        }
        // --- pass 2: generate + score (sharded, read-only) -------------
        scratch.ensure_shards(ranges.len());
        {
            let ld = &self.ld;
            let hd = &self.hd;
            let tasks: Vec<_> = scratch.shards[..ranges.len()]
                .iter_mut()
                .zip(ranges.iter().cloned())
                .map(|(sh, range)| {
                    move || {
                        sh.owners.clear();
                        sh.cands.clear();
                        sh.dists.clear();
                        for i in range {
                            sh.out.clear();
                            let mut rng = StreamRng::at(seed, iter, i as u64, lane::LD);
                            // Note the swapped table roles: LD is
                            // primary, HD is cross.
                            gen_candidates(
                                i,
                                ld,
                                hd,
                                n,
                                n_candidates,
                                routes,
                                &mut rng,
                                &mut sh.seen,
                                &mut sh.out,
                            );
                            let yi = y.row(i);
                            for &c in &sh.out {
                                sh.owners.push(i as u32);
                                sh.cands.push(c);
                                sh.dists.push(sqdist(yi, y.row(c as usize)));
                            }
                        }
                    }
                })
                .collect();
            pool.run_tasks(tasks);
        }
        // --- pass 3a: primary inserts (sharded, disjoint rows) ---------
        let n_new: usize = {
            let tasks: Vec<_> = self
                .ld
                .rows_mut(&ranges)
                .into_iter()
                .zip(scratch.shards[..ranges.len()].iter())
                .map(|(mut view, sh)| {
                    move || apply_primary(&mut view, &sh.owners, &sh.cands, &sh.dists, |_| {})
                })
                .collect();
            pool.run_tasks(tasks).into_iter().sum()
        };
        // --- pass 3b: symmetric inserts (fixed shard-then-point order) -
        for sh in &scratch.shards[..ranges.len()] {
            for t in 0..sh.owners.len() {
                // i may be a good neighbour for c; result deliberately
                // unused (LD symmetric improvements carry no flag).
                self.ld.insert(sh.cands[t] as usize, sh.owners[t], sh.dists[t]);
            }
        }
        n_new
    }

    /// Pass 1 of an HD refinement sweep: sharded candidate generation
    /// from per-point counter streams (`lane::HD`) into `scratch`'s
    /// flat pair arrays, in shard-then-point order. Read-only on the
    /// tables. The caller scores the pairs (engine: one batched
    /// [`ComputeBackend::sqdist_batch`](crate::engine::ComputeBackend::sqdist_batch)
    /// call, so a SIMD/PJRT backend vectorizes refinement scoring with
    /// no change here; standalone: [`score_pairs_native`]) and then
    /// applies them with [`IterativeKnn::apply_hd_scored`].
    /// LD refinement scores inline with scalar [`sqdist`] on purpose —
    /// routing it through a backend whose distances differ in the last
    /// bits (SIMD lane folds) would perturb native trajectories.
    #[allow(clippy::too_many_arguments)]
    pub fn gen_hd_candidates(
        &self,
        n_candidates: usize,
        routes: CandidateRoutes,
        seed: u64,
        iter: u64,
        pool: &WorkerPool,
        min_points_per_shard: usize,
        scratch: &mut RefineScratch,
    ) {
        let n = self.n();
        scratch.owners.clear();
        scratch.cands.clear();
        scratch.spans.clear();
        if n < 2 {
            scratch.ranges.clear();
            return;
        }
        let shards = effective_shards(pool, n, min_points_per_shard);
        let ranges = shard_ranges(n, shards);
        scratch.ensure_shards(ranges.len());
        {
            let hd = &self.hd;
            let ld = &self.ld;
            let tasks: Vec<_> = scratch.shards[..ranges.len()]
                .iter_mut()
                .zip(ranges.iter().cloned())
                .map(|(sh, range)| {
                    move || {
                        sh.owners.clear();
                        sh.cands.clear();
                        for i in range {
                            sh.out.clear();
                            let mut rng = StreamRng::at(seed, iter, i as u64, lane::HD);
                            gen_candidates(
                                i,
                                hd,
                                ld,
                                n,
                                n_candidates,
                                routes,
                                &mut rng,
                                &mut sh.seen,
                                &mut sh.out,
                            );
                            for &c in &sh.out {
                                sh.owners.push(i as u32);
                                sh.cands.push(c);
                            }
                        }
                    }
                })
                .collect();
            pool.run_tasks(tasks);
        }
        let RefineScratch { shards, owners, cands, spans, .. } = &mut *scratch;
        for sh in &shards[..ranges.len()] {
            spans.push(sh.owners.len());
            owners.extend_from_slice(&sh.owners);
            cands.extend_from_slice(&sh.cands);
        }
        scratch.ranges = ranges;
    }

    /// Pass 2 of an HD refinement sweep: apply scored candidates.
    /// `dists[t]` scores the pair `(owners[t], cands[t])` of the
    /// preceding [`IterativeKnn::gen_hd_candidates`] call. Primary
    /// inserts (and their dirty flags) go in sharded over disjoint row
    /// ranges, then symmetric inserts run on the calling thread in
    /// fixed shard-then-point order. Returns the number of points whose
    /// primary inserts improved — the paper's per-sweep `N_new`.
    pub fn apply_hd_scored(
        &mut self,
        dists: &[f32],
        pool: &WorkerPool,
        scratch: &RefineScratch,
    ) -> usize {
        debug_assert_eq!(dists.len(), scratch.owners.len());
        if scratch.owners.is_empty() {
            return 0;
        }
        let ranges = &scratch.ranges;
        let n_new: usize = {
            let views = self.hd.rows_mut(ranges);
            // hd_dirty chunks matching the row ranges.
            let dirty_chunks = split_by_ranges(self.hd_dirty.as_mut_slice(), ranges, 1);
            let mut tasks = Vec::with_capacity(views.len());
            let mut off = 0usize;
            for ((mut view, dirty), &span) in
                views.into_iter().zip(dirty_chunks).zip(&scratch.spans)
            {
                let owners = &scratch.owners[off..off + span];
                let cands = &scratch.cands[off..off + span];
                let ds = &dists[off..off + span];
                off += span;
                tasks.push(move || {
                    let start = view.start();
                    apply_primary(&mut view, owners, cands, ds, |i| {
                        dirty[i as usize - start] = true;
                    })
                });
            }
            pool.run_tasks(tasks).into_iter().sum()
        };
        // Symmetric insertion: i may be a good neighbour for c. Counted
        // via the dirty flag, not n_new, to keep the paper's "points
        // that received new neighbours" per-sweep semantics.
        for t in 0..scratch.owners.len() {
            let c = scratch.cands[t];
            if self.hd.insert(c as usize, scratch.owners[t], dists[t]) {
                self.hd_dirty[c as usize] = true;
            }
        }
        n_new
    }

    /// One HD refinement sweep with native (pure Rust, sharded)
    /// scoring: generate → score → apply. The engine uses the split
    /// form instead so a whole sweep's candidates become one batched
    /// backend call; this composition serves the standalone KNN tests
    /// and benches. Returns `N_new`.
    #[allow(clippy::too_many_arguments)]
    pub fn refine_hd_native(
        &mut self,
        x: &Matrix,
        n_candidates: usize,
        routes: CandidateRoutes,
        seed: u64,
        iter: u64,
        pool: &WorkerPool,
        min_points_per_shard: usize,
        scratch: &mut RefineScratch,
    ) -> usize {
        self.gen_hd_candidates(
            n_candidates,
            routes,
            seed,
            iter,
            pool,
            min_points_per_shard,
            scratch,
        );
        {
            let RefineScratch { owners, cands, dists, .. } = &mut *scratch;
            score_pairs_native(x, owners, cands, pool, MIN_SCORE_PAIRS_PER_SHARD, dists);
        }
        self.apply_hd_scored(&scratch.dists, pool, scratch)
    }

    /// Dynamic insertion: append a point (its sets start empty and fill
    /// up over subsequent refinement sweeps — the "no overhead" claim).
    pub fn push_point(&mut self) {
        self.hd.push_point();
        self.ld.push_point();
        self.hd_dirty.push(true);
    }

    /// Dynamic removal bookkeeping for `swap_remove` semantics: point
    /// `gone` disappears; the previously-last point (if different) now
    /// has index `gone`.
    pub fn swap_remove_point(&mut self, gone: usize) {
        let last = self.n() - 1;
        let moved = if gone != last { Some(last as u32) } else { None };
        self.hd.swap_rows(gone, last);
        self.ld.swap_rows(gone, last);
        self.hd_dirty.swap(gone, last);
        self.hd.pop_point();
        self.ld.pop_point();
        self.hd_dirty.pop();
        self.hd.purge(gone as u32, moved);
        self.ld.purge(gone as u32, moved);
    }
}

/// Score candidate pairs natively: `out[t] = ||x[owners[t]] −
/// x[cands[t]]||²`, sharded by pair ranges over `pool` (each output
/// element is independent, so any partition is bitwise-identical).
pub fn score_pairs_native(
    x: &Matrix,
    owners: &[u32],
    cands: &[u32],
    pool: &WorkerPool,
    min_pairs_per_shard: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(owners.len(), cands.len());
    let len = owners.len();
    if out.len() != len {
        // Every element is overwritten below, so stale contents never
        // leak; skipping the clear avoids a per-sweep memset.
        out.clear();
        out.resize(len, 0.0);
    }
    let ranges = shard_ranges(len, effective_shards(pool, len, min_pairs_per_shard));
    let chunks = split_by_ranges(out.as_mut_slice(), &ranges, 1);
    let tasks: Vec<_> = chunks
        .into_iter()
        .zip(ranges)
        .map(|(chunk, range)| {
            move || {
                let start = range.start;
                for t in range {
                    chunk[t - start] =
                        sqdist(x.row(owners[t] as usize), x.row(cands[t] as usize));
                }
            }
        })
        .collect();
    pool.run_tasks(tasks);
}

/// Generate up to `budget` candidate neighbour ids for point `i`.
///
/// `primary` is the table being refined; `other` is the twin table in
/// the opposite space (the cross-pollination source). Candidates are
/// deduplicated against each other (via the generation-stamped `seen`
/// scratch — O(1) per candidate, no per-call clearing) and against `i`;
/// they may already be in the table (insert rejects those cheaply).
///
/// Generic over the random source: the engine's sharded sweeps pass a
/// per-point [`StreamRng`], which is what makes a sweep's candidate set
/// independent of the thread count.
#[allow(clippy::too_many_arguments)]
pub fn gen_candidates<R: RandomSource>(
    i: usize,
    primary: &NeighborTable,
    other: &NeighborTable,
    n: usize,
    budget: usize,
    routes: CandidateRoutes,
    rng: &mut R,
    seen: &mut SeenStamp,
    out: &mut Vec<u32>,
) {
    debug_assert!(out.is_empty());
    if n < 2 {
        return;
    }
    seen.begin(n);
    let push = |c: u32, out: &mut Vec<u32>, seen: &mut SeenStamp| {
        if c as usize != i && seen.mark(c) {
            out.push(c);
        }
    };
    // Route 1 — same-space neighbours of neighbours: pick a random
    // neighbour j, then a random neighbour of j.
    if routes.same_space {
        let tries = budget.div_ceil(2);
        for _ in 0..tries {
            let nb = primary.neighbors(i);
            if nb.is_empty() {
                break;
            }
            let j = nb[rng.below(nb.len())] as usize;
            let nb2 = primary.neighbors(j);
            if !nb2.is_empty() {
                push(nb2[rng.below(nb2.len())], out, seen);
            } else {
                push(j as u32, out, seen);
            }
        }
    }
    // Route 2+3 — cross-space: direct twin neighbours and twin
    // neighbours-of-neighbours.
    if routes.cross_space {
        let nb = other.neighbors(i);
        let tries = budget.div_ceil(2);
        for t in 0..tries {
            if nb.is_empty() {
                break;
            }
            let j = nb[rng.below(nb.len())];
            if t % 2 == 0 {
                push(j, out, seen);
            } else {
                let nb2 = other.neighbors(j as usize);
                if !nb2.is_empty() {
                    push(nb2[rng.below(nb2.len())], out, seen);
                } else {
                    push(j, out, seen);
                }
            }
        }
    }
    // Route 4 — uniform random escape hatch.
    if routes.random {
        let tries = (budget / 4).max(1);
        for _ in 0..tries {
            push(rng.below(n) as u32, out, seen);
        }
    }
    out.truncate(budget.max(1));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;

    fn recall(truth: &NeighborTable, approx: &NeighborTable) -> f64 {
        let n = truth.n();
        let (mut hit, mut tot) = (0usize, 0usize);
        for i in 0..n {
            for j in truth.neighbors(i) {
                tot += 1;
                if approx.contains(i, *j) {
                    hit += 1;
                }
            }
        }
        hit as f64 / tot.max(1) as f64
    }

    /// With a *perfect* LD embedding (LD = HD), cross-space candidates
    /// should drive HD recall high quickly — the feedback-loop premise.
    #[test]
    fn converges_with_identity_embedding() {
        let ds = datasets::blobs(500, 8, 5, 0.8, 10.0, 1);
        let mut rng = crate::util::Rng::new(7);
        let mut knn = IterativeKnn::new(500, 10, 10);
        // LD == HD here (the best possible embedding).
        knn.seed_random(&ds.x, &ds.x, &mut rng);
        let pool = WorkerPool::new(2);
        let mut scratch = RefineScratch::default();
        for round in 1..=40u64 {
            knn.refine_hd_native(
                &ds.x,
                8,
                CandidateRoutes::default(),
                7,
                round,
                &pool,
                1,
                &mut scratch,
            );
            knn.refine_ld(
                &ds.x,
                8,
                CandidateRoutes::default(),
                7,
                round,
                &pool,
                1,
                &mut scratch,
            );
        }
        let truth = brute_knn(&ds.x, 10);
        let r = recall(&truth, &knn.hd);
        assert!(r > 0.85, "iterative KNN recall {r}");
    }

    /// Random-route-only ablation must converge more slowly than the
    /// full candidate mix (the candidate routes matter).
    #[test]
    fn routes_beat_random_only() {
        let ds = datasets::blobs(400, 8, 4, 0.8, 10.0, 2);
        let truth = brute_knn(&ds.x, 8);
        let run = |routes: CandidateRoutes, seed: u64| {
            let mut rng = crate::util::Rng::new(seed);
            let mut knn = IterativeKnn::new(400, 8, 8);
            knn.seed_random(&ds.x, &ds.x, &mut rng);
            let pool = WorkerPool::new(1);
            let mut scratch = RefineScratch::default();
            for round in 1..=15u64 {
                knn.refine_hd_native(&ds.x, 8, routes, seed, round, &pool, 1, &mut scratch);
                knn.refine_ld(&ds.x, 8, routes, seed, round, &pool, 1, &mut scratch);
            }
            recall(&truth, &knn.hd)
        };
        let full = run(CandidateRoutes::default(), 3);
        let rand_only =
            run(CandidateRoutes { same_space: false, cross_space: false, random: true }, 3);
        assert!(
            full > rand_only + 0.05,
            "full routes {full} should beat random-only {rand_only}"
        );
    }

    /// Refinement only ever replaces a stored neighbour with a strictly
    /// closer one, so (with table k == truth k and distinct distances)
    /// the hit count against exact ground truth can never drop: an
    /// insert that evicts a true top-k member admits a point that is
    /// itself inside the true top-k radius. This is the invariant the
    /// online quality probe's `knn_recall_hd` trajectory relies on.
    #[test]
    fn property_recall_vs_brute_non_decreasing_over_rounds() {
        use crate::util::proptest as pt;
        pt::check("iterative-recall-monotone", 6, |rng, _| {
            let n = rng.range_usize(120, 250);
            let seed = rng.next_u64();
            let ds = datasets::blobs(n, 6, 3, 0.6, 8.0, seed);
            let k = 8usize;
            let truth = brute_knn(&ds.x, k);
            let mut krng = crate::util::Rng::new(seed ^ 0x51);
            let mut knn = IterativeKnn::new(n, k, k);
            knn.seed_random(&ds.x, &ds.x, &mut krng);
            let hits = |knn: &IterativeKnn| -> usize {
                (0..n)
                    .map(|i| {
                        truth.neighbors(i).iter().filter(|&&j| knn.hd.contains(i, j)).count()
                    })
                    .sum()
            };
            let pool = WorkerPool::new(2);
            let mut scratch = RefineScratch::default();
            let mut prev = hits(&knn);
            for round in 1..=15u64 {
                knn.refine_hd_native(
                    &ds.x,
                    8,
                    CandidateRoutes::default(),
                    seed,
                    round,
                    &pool,
                    1,
                    &mut scratch,
                );
                knn.refine_ld(
                    &ds.x,
                    8,
                    CandidateRoutes::default(),
                    seed,
                    round,
                    &pool,
                    1,
                    &mut scratch,
                );
                let h = hits(&knn);
                crate::prop_assert!(
                    h >= prev,
                    "recall dropped at round {round}: {h} < {prev} (n = {n})"
                );
                prev = h;
            }
            let recall = prev as f64 / (n * k) as f64;
            crate::prop_assert!(recall > 0.4, "recall never improved: {recall} (n = {n})");
            Ok(())
        });
    }

    /// The new determinism contract: a refinement sweep is bitwise
    /// thread-count-invariant — tables, stored distances and dirty
    /// flags agree exactly at any pool width and shard partition.
    #[test]
    fn refinement_bitwise_invariant_across_thread_counts() {
        let ds = datasets::blobs(300, 6, 3, 0.6, 8.0, 17);
        let n = 300usize;
        // A rough "embedding": the first two data dimensions.
        let mut yv = Vec::with_capacity(n * 2);
        for i in 0..n {
            yv.extend_from_slice(&ds.x.row(i)[..2]);
        }
        let y = Matrix::from_vec(yv, n, 2).unwrap();
        let run = |threads: usize| -> (IterativeKnn, Vec<usize>) {
            let mut rng = crate::util::Rng::new(3);
            let mut knn = IterativeKnn::new(n, 8, 6);
            knn.seed_random(&ds.x, &y, &mut rng);
            let pool = WorkerPool::new(threads);
            let mut scratch = RefineScratch::default();
            let mut n_news = Vec::new();
            for round in 1..=10u64 {
                n_news.push(knn.refine_ld(
                    &y,
                    8,
                    CandidateRoutes::default(),
                    99,
                    round,
                    &pool,
                    1,
                    &mut scratch,
                ));
                n_news.push(knn.refine_hd_native(
                    &ds.x,
                    8,
                    CandidateRoutes::default(),
                    99,
                    round,
                    &pool,
                    1,
                    &mut scratch,
                ));
            }
            (knn, n_news)
        };
        let state = |t: &NeighborTable| -> Vec<Vec<(u32, u32)>> {
            (0..n).map(|i| t.entries(i).map(|(j, d)| (j, d.to_bits())).collect()).collect()
        };
        let (base, base_news) = run(1);
        for threads in [2usize, 4, 7] {
            let (other, other_news) = run(threads);
            assert_eq!(base_news, other_news, "N_new differs at {threads} threads");
            assert_eq!(
                state(&base.hd),
                state(&other.hd),
                "hd table differs at {threads} threads"
            );
            assert_eq!(
                state(&base.ld),
                state(&other.ld),
                "ld table differs at {threads} threads"
            );
            assert_eq!(base.hd_dirty, other.hd_dirty, "dirty flags differ at {threads} threads");
        }
    }

    #[test]
    fn gen_candidates_dedups_and_excludes_self() {
        let mut primary = NeighborTable::new(10, 4);
        let mut other = NeighborTable::new(10, 4);
        for j in 1..5u32 {
            primary.insert(0, j, j as f32);
            other.insert(0, j + 4, j as f32);
        }
        let mut seen = SeenStamp::default();
        let mut out = Vec::new();
        for t in 0..20u64 {
            out.clear();
            let mut rng = StreamRng::at(5, t, 0, lane::HD);
            gen_candidates(
                0,
                &primary,
                &other,
                10,
                12,
                CandidateRoutes::default(),
                &mut rng,
                &mut seen,
                &mut out,
            );
            assert!(!out.contains(&0), "self in candidates");
            let set: std::collections::HashSet<_> = out.iter().collect();
            assert_eq!(set.len(), out.len(), "duplicates in candidates");
            assert!(out.len() <= 12);
        }
    }

    /// The stamp scratch survives reuse across points and iterations
    /// without clearing: candidates fresh for one point stay fresh for
    /// the next even when ids repeat.
    #[test]
    fn seen_stamp_resets_per_generation_without_clearing() {
        let mut seen = SeenStamp::default();
        seen.begin(8);
        assert!(seen.mark(3));
        assert!(!seen.mark(3), "duplicate within a generation");
        assert!(seen.mark(5));
        seen.begin(8);
        assert!(seen.mark(3), "previous generation must not leak");
        assert!(seen.mark(5));
        // Growing n mid-life keeps old stamps valid.
        seen.begin(16);
        assert!(seen.mark(15));
        assert!(seen.mark(3));
        assert!(!seen.mark(15));
    }

    #[test]
    fn dirty_flags_set_on_discovery() {
        let ds = datasets::blobs(100, 4, 2, 0.5, 6.0, 4);
        let mut rng = crate::util::Rng::new(9);
        let mut knn = IterativeKnn::new(100, 6, 6);
        knn.seed_random(&ds.x, &ds.x, &mut rng);
        knn.hd_dirty.iter_mut().for_each(|f| *f = false);
        let pool = WorkerPool::new(2);
        let mut scratch = RefineScratch::default();
        let n_new = knn.refine_hd_native(
            &ds.x,
            8,
            CandidateRoutes::default(),
            9,
            1,
            &pool,
            1,
            &mut scratch,
        );
        let dirty = knn.hd_dirty.iter().filter(|&&f| f).count();
        assert!(dirty >= n_new, "dirty {dirty} < n_new {n_new}");
        assert!(n_new > 0, "refinement found nothing on a fresh random table");
    }

    #[test]
    fn dynamic_push_and_remove_keep_tables_consistent() {
        let ds = datasets::blobs(60, 4, 2, 0.5, 6.0, 6);
        let mut rng = crate::util::Rng::new(11);
        let mut knn = IterativeKnn::new(60, 5, 5);
        knn.seed_random(&ds.x, &ds.x, &mut rng);
        knn.push_point();
        assert_eq!(knn.n(), 61);
        knn.swap_remove_point(10);
        assert_eq!(knn.n(), 60);
        // No table may reference an out-of-range index.
        for i in 0..knn.n() {
            for &j in knn.hd.neighbors(i) {
                assert!((j as usize) < knn.n(), "stale hd ref {j}");
            }
            for &j in knn.ld.neighbors(i) {
                assert!((j as usize) < knn.n(), "stale ld ref {j}");
            }
        }
    }

    #[test]
    fn score_pairs_native_matches_direct_at_any_width() {
        let ds = datasets::blobs(50, 7, 2, 1.0, 5.0, 9);
        let owners: Vec<u32> = (0..37).collect();
        let cands: Vec<u32> = (10..47).collect();
        let mut expect = Vec::new();
        for t in 0..owners.len() {
            expect.push(sqdist(ds.x.row(owners[t] as usize), ds.x.row(cands[t] as usize)));
        }
        for threads in [1usize, 2, 5] {
            let pool = WorkerPool::new(threads);
            let mut out = Vec::new();
            score_pairs_native(&ds.x, &owners, &cands, &pool, 1, &mut out);
            assert_eq!(out.len(), expect.len());
            for (a, b) in out.iter().zip(&expect) {
                assert_eq!(a.to_bits(), b.to_bits(), "score differs at {threads} threads");
            }
        }
    }
}
