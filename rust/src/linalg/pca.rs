//! Principal component analysis by power iteration with deflation.
//!
//! Works on the covariance implicitly (X^T X / n applied as two matvecs),
//! so memory stays O(n·d + k·d) even for wide matrices. Accuracy is more
//! than sufficient for preprocessing and linear views; components are
//! refined until the Rayleigh quotient stabilises.

use crate::data::matrix::{dot, Matrix};
use crate::util::Rng;

/// A fitted PCA basis.
#[derive(Clone, Debug)]
pub struct Pca {
    /// (k, d) row-major principal axes (orthonormal rows).
    pub components: Matrix,
    /// Column means of the training data.
    pub means: Vec<f32>,
    /// Explained variance per component (eigenvalues of cov).
    pub explained: Vec<f64>,
}

impl Pca {
    /// Dimensionality of the space the basis was fitted on (rows given
    /// to [`Pca::transform`] must have this many columns).
    pub fn input_dim(&self) -> usize {
        self.components.d()
    }

    /// Dimensionality of the projected space (number of components).
    pub fn out_dim(&self) -> usize {
        self.components.n()
    }

    /// Fit `k` components on `x` (not modified).
    pub fn fit(x: &Matrix, k: usize, seed: u64) -> Pca {
        let n = x.n();
        let d = x.d();
        let k = k.min(d).min(n.max(1));
        let means = x.col_means();
        let mut rng = Rng::new(seed ^ 0x9E37);
        let mut comps = Matrix::zeros(k, d);
        let mut explained = Vec::with_capacity(k);
        // Centered row access without materialising a copy.
        let centered_dot = |row: &[f32], v: &[f32], _means: &[f32], mv: f32| -> f32 {
            // (row - means) . v  given mv = means . v precomputed
            dot(row, v) - mv
        };
        for c in 0..k {
            // Init random unit vector, orthogonal to found components.
            let mut v: Vec<f32> = (0..d).map(|_| rng.gauss() as f32).collect();
            orthonormalize(&mut v, &comps, c);
            let mut lambda_prev = f64::INFINITY;
            let mut lambda = 0.0f64;
            for _iter in 0..200 {
                // w = Cov v = X_c^T (X_c v) / n
                let mv = dot(&means, &v);
                let mut w = vec![0.0f32; d];
                for i in 0..n {
                    let row = x.row(i);
                    let s = centered_dot(row, &v, &means, mv);
                    if s != 0.0 {
                        for j in 0..d {
                            w[j] += s * (row[j] - means[j]);
                        }
                    }
                }
                let inv_n = 1.0 / n.max(1) as f32;
                for wj in w.iter_mut() {
                    *wj *= inv_n;
                }
                orthonormalize_raw(&mut w, &comps, c);
                let norm = dot(&w, &w).sqrt();
                if norm < 1e-12 {
                    break; // exhausted variance
                }
                for wj in w.iter_mut() {
                    *wj /= norm;
                }
                lambda = norm as f64;
                v = w;
                if (lambda - lambda_prev).abs() <= 1e-9 * lambda.max(1e-30) {
                    break;
                }
                lambda_prev = lambda;
            }
            comps.row_mut(c).copy_from_slice(&v);
            explained.push(lambda);
        }
        Pca { components: comps, means, explained }
    }

    /// Project `x` onto the fitted basis → (n, k).
    pub fn transform(&self, x: &Matrix) -> Matrix {
        let n = x.n();
        let k = self.components.n();
        let mut out = Matrix::zeros(n, k);
        let mk: Vec<f32> = (0..k).map(|c| dot(&self.means, self.components.row(c))).collect();
        for i in 0..n {
            let row = x.row(i);
            let orow = out.row_mut(i);
            for c in 0..k {
                orow[c] = dot(row, self.components.row(c)) - mk[c];
            }
        }
        out
    }

    /// Convenience: fit + transform.
    pub fn fit_transform(x: &Matrix, k: usize, seed: u64) -> Matrix {
        Pca::fit(x, k, seed).transform(x)
    }

    /// Fraction of total variance captured (needs total variance of x).
    pub fn explained_ratio(&self, x: &Matrix) -> f64 {
        let n = x.n();
        let means = &self.means;
        let mut total = 0.0f64;
        for i in 0..n {
            for (k, &v) in x.row(i).iter().enumerate() {
                let c = (v - means[k]) as f64;
                total += c * c;
            }
        }
        total /= n.max(1) as f64;
        if total <= 0.0 {
            return 1.0;
        }
        self.explained.iter().sum::<f64>() / total
    }
}

fn orthonormalize(v: &mut [f32], comps: &Matrix, upto: usize) {
    orthonormalize_raw(v, comps, upto);
    let norm = dot(v, v).sqrt().max(1e-12);
    for x in v.iter_mut() {
        *x /= norm;
    }
}

fn orthonormalize_raw(v: &mut [f32], comps: &Matrix, upto: usize) {
    for c in 0..upto {
        let b = comps.row(c);
        let proj = dot(v, b);
        for (vk, bk) in v.iter_mut().zip(b) {
            *vk -= proj * bk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Build data with a known dominant axis.
    fn anisotropic(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            let t = rng.gauss_ms(0.0, 5.0); // big variance along axis 0+1
            let row = x.row_mut(i);
            row[0] = t as f32;
            row[1] = t as f32 * 0.5;
            for k in 2..d {
                row[k] = rng.gauss_ms(0.0, 0.3) as f32;
            }
        }
        x
    }

    #[test]
    fn first_component_finds_dominant_axis() {
        let x = anisotropic(400, 6, 1);
        let pca = Pca::fit(&x, 2, 0);
        let c0 = pca.components.row(0);
        // Dominant direction ∝ (1, 0.5, 0, ...) normalised.
        let expect = {
            let norm = (1.0f32 + 0.25).sqrt();
            [1.0 / norm, 0.5 / norm]
        };
        let align = (c0[0] * expect[0] + c0[1] * expect[1]).abs();
        assert!(align > 0.99, "alignment {align}, c0={c0:?}");
    }

    #[test]
    fn components_are_orthonormal() {
        let x = anisotropic(300, 8, 2);
        let pca = Pca::fit(&x, 4, 0);
        for a in 0..4 {
            for b in 0..4 {
                let d = dot(pca.components.row(a), pca.components.row(b));
                let expect = if a == b { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < 1e-3, "({a},{b}) dot={d}");
            }
        }
    }

    #[test]
    fn eigenvalues_decrease() {
        let x = anisotropic(300, 8, 3);
        let pca = Pca::fit(&x, 4, 0);
        for w in pca.explained.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "eigenvalues not sorted: {:?}", pca.explained);
        }
    }

    #[test]
    fn transform_centers_projection() {
        let x = anisotropic(200, 5, 4);
        let pca = Pca::fit(&x, 3, 0);
        let y = pca.transform(&x);
        assert_eq!(y.n(), 200);
        assert_eq!(y.d(), 3);
        for m in y.col_means() {
            assert!(m.abs() < 1e-3, "projected mean {m}");
        }
    }

    #[test]
    fn explained_ratio_close_to_one_with_full_rank() {
        let x = anisotropic(150, 4, 5);
        let pca = Pca::fit(&x, 4, 0);
        let r = pca.explained_ratio(&x);
        assert!(r > 0.98, "ratio {r}");
    }
}
