//! Small dense linear algebra: PCA and MDS.
//!
//! Both are substrates the paper depends on: PCA for preprocessing
//! (ImageNet 1280→192, the recommended 50-100-component reduction before
//! NE) and for Figs 1/2/11; classical MDS + SMACOF for the Fig. 2
//! method comparison.

pub mod pca;
pub mod mds;

pub use pca::Pca;
