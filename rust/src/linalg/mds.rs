//! Multidimensional scaling: classical (Torgerson) and SMACOF stress
//! majorisation, used by the Fig. 2 method panel.
//!
//! For large N the figure drivers subsample (MDS is O(N²) by nature —
//! the paper uses it only as a qualitative global-structure reference).

use crate::data::matrix::{dist, Matrix};
use crate::util::Rng;

/// Classical MDS: double-centre the squared distance matrix and take the
/// top `k` eigenvectors by power iteration.
pub fn classical_mds(x: &Matrix, k: usize, seed: u64) -> Matrix {
    let n = x.n();
    // B = -0.5 J D² J, J = I - 11ᵀ/n.
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let dd = x.sqdist(i, j) as f64;
            d2[i * n + j] = dd;
            d2[j * n + i] = dd;
        }
    }
    let mut row_mean = vec![0.0f64; n];
    let mut total = 0.0f64;
    for i in 0..n {
        let s: f64 = d2[i * n..(i + 1) * n].iter().sum();
        row_mean[i] = s / n as f64;
        total += s;
    }
    total /= (n * n) as f64;
    let mut b = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            b[i * n + j] = -0.5 * (d2[i * n + j] - row_mean[i] - row_mean[j] + total);
        }
    }
    // Power iteration with deflation on B (n×n, f64).
    let mut rng = Rng::new(seed ^ 0x4D44_53); // "MDS" salt
    let mut out = Matrix::zeros(n, k);
    let mut basis: Vec<Vec<f64>> = Vec::new();
    for c in 0..k {
        let mut v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
        let mut lambda = 0.0f64;
        for _ in 0..300 {
            // Orthogonalise against found eigenvectors.
            for bv in &basis {
                let proj: f64 = v.iter().zip(bv).map(|(a, b)| a * b).sum();
                for (vk, bk) in v.iter_mut().zip(bv) {
                    *vk -= proj * bk;
                }
            }
            let mut w = vec![0.0f64; n];
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += b[i * n + j] * v[j];
                }
                w[i] = s;
            }
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-14 {
                break;
            }
            for wk in w.iter_mut() {
                *wk /= norm;
            }
            let delta: f64 = w.iter().zip(&v).map(|(a, b)| (a - b).abs()).sum();
            v = w;
            lambda = norm;
            if delta < 1e-10 {
                break;
            }
        }
        let scale = lambda.max(0.0).sqrt();
        for i in 0..n {
            out.row_mut(i)[c] = (v[i] * scale) as f32;
        }
        basis.push(v);
    }
    out
}

/// SMACOF stress majorisation from a given (or random) init.
///
/// Minimises raw stress Σ (d_ij - δ_ij)² with uniform weights via the
/// Guttman transform. O(N²·iters).
pub fn smacof(x: &Matrix, k: usize, iters: usize, seed: u64) -> Matrix {
    let n = x.n();
    let mut rng = Rng::new(seed);
    let mut y = Matrix::zeros(n, k);
    for v in y.data_mut() {
        *v = rng.gauss_ms(0.0, 1.0) as f32;
    }
    let mut delta = vec![0.0f32; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let dd = dist(x.row(i), x.row(j));
            delta[i * n + j] = dd;
            delta[j * n + i] = dd;
        }
    }
    let mut ynew = Matrix::zeros(n, k);
    for _ in 0..iters {
        for v in ynew.data_mut() {
            *v = 0.0;
        }
        for i in 0..n {
            let mut diag = 0.0f32;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let dij = dist(y.row(i), y.row(j)).max(1e-9);
                let ratio = delta[i * n + j] / dij;
                diag += ratio;
                // B_ij = -ratio; accumulate (B Y)_i
                let yj = y.row(j);
                // Copy to avoid double borrow: accumulate into temp slice.
                for c in 0..k {
                    ynew.data_mut()[i * k + c] -= ratio * yj[c];
                }
            }
            let yi = y.row(i);
            for c in 0..k {
                ynew.data_mut()[i * k + c] += diag * yi[c];
            }
        }
        let inv_n = 1.0 / n as f32;
        for v in ynew.data_mut() {
            *v *= inv_n;
        }
        std::mem::swap(&mut y, &mut ynew);
    }
    y
}

/// Raw stress of an embedding vs HD distances (for tests).
pub fn stress(x: &Matrix, y: &Matrix) -> f64 {
    let n = x.n();
    let mut s = 0.0f64;
    for i in 0..n {
        for j in (i + 1)..n {
            let dh = dist(x.row(i), x.row(j)) as f64;
            let dl = dist(y.row(i), y.row(j)) as f64;
            s += (dh - dl) * (dh - dl);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::Rng;

    /// A planar cloud embedded in 5-D: MDS in 2-D must recover distances
    /// nearly exactly.
    fn planar(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut x = Matrix::zeros(n, 5);
        for i in 0..n {
            let (a, b) = (rng.gauss_ms(0.0, 3.0), rng.gauss_ms(0.0, 1.5));
            let row = x.row_mut(i);
            // plane spanned by two fixed directions
            row[0] = a as f32;
            row[1] = (0.5 * a + b) as f32;
            row[2] = b as f32;
            row[3] = (a - b) as f32 * 0.2;
            row[4] = 0.0;
        }
        x
    }

    #[test]
    fn classical_mds_recovers_planar_distances() {
        let x = planar(80, 1);
        let y = classical_mds(&x, 2, 0);
        // Compare pairwise distances: Spearman should be ~1.
        let mut dh = Vec::new();
        let mut dl = Vec::new();
        for i in 0..x.n() {
            for j in (i + 1)..x.n() {
                dh.push(dist(x.row(i), x.row(j)) as f64);
                dl.push(dist(y.row(i), y.row(j)) as f64);
            }
        }
        let rho = crate::util::stats::pearson(&dh, &dl);
        assert!(rho > 0.95, "distance correlation {rho}");
    }

    #[test]
    fn smacof_reduces_stress() {
        let x = planar(50, 2);
        let y0 = smacof(&x, 2, 1, 3);
        let y = smacof(&x, 2, 60, 3);
        assert!(
            stress(&x, &y) < stress(&x, &y0) * 0.5,
            "SMACOF failed to reduce stress: {} -> {}",
            stress(&x, &y0),
            stress(&x, &y)
        );
    }

    #[test]
    fn smacof_output_is_finite() {
        pt::check("smacof-finite", 8, |rng, _| {
            let n = rng.range_usize(10, 30);
            let x = Matrix::from_vec(pt::gauss_mat(rng, n, 4, 2.0), n, 4).unwrap();
            let y = smacof(&x, 2, 10, rng.next_u64());
            crate::prop_assert!(
                y.data().iter().all(|v| v.is_finite()),
                "non-finite SMACOF output"
            );
            Ok(())
        });
    }
}
