//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them
//! from the Rust hot path (never touching Python at run time).

pub mod artifacts;
pub mod pjrt;

pub use artifacts::Manifest;
