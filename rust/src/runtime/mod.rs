//! Run-time substrates: the PJRT loader for AOT-lowered HLO artifacts
//! (never touching Python at run time) and the zero-dependency worker
//! pool the sharded native backend runs on.

pub mod artifacts;
pub mod pjrt;
pub mod pool;

pub use artifacts::Manifest;
pub use pool::WorkerPool;
