//! Run-time substrates: the PJRT loader for AOT-lowered HLO artifacts
//! (never touching Python at run time), the zero-dependency worker
//! pool the sharded native backend runs on, and the checked
//! synchronization primitives every lock in the crate must go through.

pub mod artifacts;
pub mod pjrt;
pub mod pool;
pub mod sync;

pub use artifacts::Manifest;
pub use pool::WorkerPool;
pub use sync::{DebugCondvar, DebugMutex};
