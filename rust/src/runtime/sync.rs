//! Checked synchronization primitives: the only place in the crate
//! allowed to touch `std::sync::Mutex`/`Condvar` directly (lint rule
//! `raw_sync` enforces this).
//!
//! [`DebugMutex`] and [`DebugCondvar`] behave like their `std`
//! counterparts with two differences:
//!
//! * **Poison recovery is centralized.** A thread panicking while
//!   holding a lock poisons it; every caller here recovers with
//!   [`PoisonError::into_inner`] instead of sprinkling
//!   `unwrap_or_else` at each call site. That matches the server's
//!   needs: queue state stays usable (a closed/lagged flag is always
//!   consistent on its own), and a poisoned subscriber queue must not
//!   take the whole stepper down.
//!
//! * **Lock-order checking under `cfg(debug_assertions)`.** Every
//!   mutex belongs to a named **class** (the `name` passed to
//!   [`DebugMutex::new`]; instances sharing a name share a class). A
//!   global graph records, per class pair, the nesting order actually
//!   observed at runtime; an acquisition that would close a cycle —
//!   the classic A→B / B→A deadlock — **panics immediately with both
//!   lock names and the established path**, instead of deadlocking
//!   some future run that happens to interleave badly. Acquiring two
//!   locks of the *same* class on one thread also panics: class-level
//!   ranking cannot order them, so such nesting must be redesigned
//!   (the FrameHub, for instance, locks one subscriber queue at a
//!   time, never two).
//!
//! In release builds the order bookkeeping compiles out entirely;
//! what remains is `std::sync` plus one niche-optimized `Option`
//! around the guard (same size as the raw guard). Waiting on a
//! condvar keeps the class marked held: the region is still logically
//! owned, so no new ordering edges can form mid-wait.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// A named, order-checked, poison-recovering mutex.
pub struct DebugMutex<T> {
    inner: Mutex<T>,
    #[cfg(debug_assertions)]
    class: usize,
}

impl<T> DebugMutex<T> {
    /// Wrap `value` in a mutex belonging to the lock class `name`.
    /// Instances sharing a name share ordering constraints.
    pub fn new(name: &'static str, value: T) -> DebugMutex<T> {
        #[cfg(not(debug_assertions))]
        let _ = name;
        DebugMutex {
            inner: Mutex::new(value),
            #[cfg(debug_assertions)]
            class: order::register(name),
        }
    }

    /// Acquire the lock, recovering from poison. Under
    /// `debug_assertions`, panics if this acquisition would close a
    /// lock-order cycle (see module docs).
    pub fn lock(&self) -> DebugMutexGuard<'_, T> {
        #[cfg(debug_assertions)]
        order::acquire(self.class);
        let guard = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        DebugMutexGuard {
            guard: Some(guard),
            #[cfg(debug_assertions)]
            class: self.class,
        }
    }
}

/// RAII guard for a [`DebugMutex`]; releases the lock (and its
/// order-tracking entry) on drop.
pub struct DebugMutexGuard<'a, T> {
    /// `None` only transiently, while surrendered to a condvar wait.
    guard: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    class: usize,
}

impl<T> DebugMutexGuard<'_, T> {
    #[cfg(debug_assertions)]
    fn note_release(&self) {
        order::release(self.class);
    }

    #[cfg(not(debug_assertions))]
    fn note_release(&self) {}
}

impl<T> std::ops::Deref for DebugMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard surrendered to a condvar wait")
    }
}

impl<T> std::ops::DerefMut for DebugMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard surrendered to a condvar wait")
    }
}

impl<T> Drop for DebugMutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.guard.take().is_some() {
            self.note_release();
        }
    }
}

/// Condition variable paired with [`DebugMutex`]; recovers from
/// poison on wake.
pub struct DebugCondvar {
    inner: Condvar,
}

impl DebugCondvar {
    pub fn new() -> DebugCondvar {
        DebugCondvar { inner: Condvar::new() }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    /// Release `guard`'s lock, wait up to `timeout` for a
    /// notification, and reacquire. The guard's lock class stays
    /// marked held across the wait: the caller still logically owns
    /// the region, so no ordering edges can form mid-wait.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: DebugMutexGuard<'a, T>,
        timeout: Duration,
    ) -> (DebugMutexGuard<'a, T>, WaitTimeoutResult) {
        let inner = guard.guard.take().expect("guard already surrendered");
        let (restored, res) =
            self.inner.wait_timeout(inner, timeout).unwrap_or_else(PoisonError::into_inner);
        guard.guard = Some(restored);
        (guard, res)
    }
}

impl Default for DebugCondvar {
    fn default() -> DebugCondvar {
        DebugCondvar::new()
    }
}

/// The global lock-order registry: class names, and the directed
/// graph of observed nesting (edge a→b = "b was acquired while a was
/// held"). Debug builds only.
#[cfg(debug_assertions)]
mod order {
    use std::cell::RefCell;
    use std::collections::{BTreeMap, BTreeSet};
    use std::sync::{Mutex, OnceLock};

    struct Registry {
        ids: BTreeMap<&'static str, usize>,
        names: Vec<&'static str>,
        edges: Vec<BTreeSet<usize>>,
    }

    fn registry() -> &'static Mutex<Registry> {
        static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
        REG.get_or_init(|| {
            Mutex::new(Registry { ids: BTreeMap::new(), names: Vec::new(), edges: Vec::new() })
        })
    }

    thread_local! {
        /// Classes this thread currently holds, in acquisition order.
        static HELD: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
    }

    /// Intern `name` as a lock class id.
    pub fn register(name: &'static str) -> usize {
        let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
        if let Some(&id) = reg.ids.get(name) {
            return id;
        }
        let id = reg.names.len();
        reg.names.push(name);
        reg.edges.push(BTreeSet::new());
        reg.ids.insert(name, id);
        id
    }

    /// Record that the current thread is about to acquire `class`.
    /// Panics — *before* blocking on the real lock — when the
    /// acquisition closes an order cycle or nests a class inside
    /// itself.
    pub fn acquire(class: usize) {
        let held: Vec<usize> = HELD.with(|h| h.borrow().clone());
        let mut violation: Option<String> = None;
        if !held.is_empty() {
            let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
            if held.contains(&class) {
                violation = Some(format!(
                    "lock-order violation: acquiring a second \"{}\" while this thread \
                     already holds one; class-level ranking cannot order instances of \
                     one class, so redesign to lock them one at a time",
                    reg.names[class]
                ));
            } else if let Some((outer, path)) = cycle_path(&reg, class, &held) {
                let chain: Vec<&str> = path.iter().map(|&c| reg.names[c]).collect();
                violation = Some(format!(
                    "lock-order cycle: acquiring \"{}\" while holding \"{}\", but the \
                     reverse order {} is already established elsewhere — this \
                     interleaving can deadlock",
                    reg.names[class],
                    reg.names[outer],
                    chain.join(" -> "),
                ));
            } else {
                for &h in &held {
                    reg.edges[h].insert(class);
                }
            }
        }
        if let Some(msg) = violation {
            panic!("{msg}");
        }
        HELD.with(|h| h.borrow_mut().push(class));
    }

    /// Record that the current thread released `class`.
    pub fn release(class: usize) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held.iter().rposition(|&c| c == class) {
                held.remove(pos);
            }
        });
    }

    /// If `from` already reaches any held class in the order graph,
    /// return that class and the established path `from → … → held`.
    fn cycle_path(reg: &Registry, from: usize, held: &[usize]) -> Option<(usize, Vec<usize>)> {
        for &h in held {
            if let Some(path) = path_between(reg, from, h) {
                return Some((h, path));
            }
        }
        None
    }

    fn path_between(reg: &Registry, from: usize, to: usize) -> Option<Vec<usize>> {
        let mut parent: BTreeMap<usize, usize> = BTreeMap::new();
        let mut seen = BTreeSet::new();
        seen.insert(from);
        let mut stack = vec![from];
        while let Some(u) = stack.pop() {
            for &v in &reg.edges[u] {
                if seen.insert(v) {
                    parent.insert(v, u);
                    if v == to {
                        let mut path = vec![to];
                        let mut cur = to;
                        while let Some(&p) = parent.get(&cur) {
                            path.push(p);
                            cur = p;
                        }
                        path.reverse();
                        return Some(path);
                    }
                    stack.push(v);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_round_trip_and_mutation() {
        let m = DebugMutex::new("sync_test_round_trip", 1i32);
        *m.lock() += 41;
        assert_eq!(*m.lock(), 42);
    }

    #[test]
    fn consistent_nesting_is_allowed() {
        let outer = DebugMutex::new("sync_test_nest_outer", 0u32);
        let inner = DebugMutex::new("sync_test_nest_inner", 0u32);
        for _ in 0..3 {
            let _go = outer.lock();
            let _gi = inner.lock();
        }
        let _gi = inner.lock();
    }

    #[test]
    fn condvar_times_out_then_sees_notification() {
        let pair = Arc::new((DebugMutex::new("sync_test_cv", false), DebugCondvar::new()));
        let g = pair.0.lock();
        let (g, res) = pair.1.wait_timeout(g, Duration::from_millis(10));
        assert!(res.timed_out());
        assert!(!*g);
        drop(g);
        let waker = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            *waker.0.lock() = true;
            waker.1.notify_all();
        });
        let mut g = pair.0.lock();
        while !*g {
            let (next, _) = pair.1.wait_timeout(g, Duration::from_millis(50));
            g = next;
        }
        drop(g);
        t.join().expect("waker thread");
    }

    #[test]
    fn poisoned_lock_recovers_with_last_write() {
        let m = Arc::new(DebugMutex::new("sync_test_poison", 7i32));
        let writer = Arc::clone(&m);
        let res = std::thread::spawn(move || {
            let mut g = writer.lock();
            *g = 9;
            panic!("poison on purpose");
        })
        .join();
        assert!(res.is_err(), "thread must have panicked");
        assert_eq!(*m.lock(), 9, "poison recovered; last write visible");
    }

    #[cfg(debug_assertions)]
    fn panic_message(err: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = err.downcast_ref::<String>() {
            s.clone()
        } else if let Some(s) = err.downcast_ref::<&str>() {
            (*s).to_string()
        } else {
            String::new()
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    fn cycle_forming_acquisition_panics_with_both_names() {
        let a = DebugMutex::new("sync_test_cycle_a", ());
        let b = DebugMutex::new("sync_test_cycle_b", ());
        {
            let _ga = a.lock();
            let _gb = b.lock(); // establishes a → b
        }
        let gb = b.lock();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ga = a.lock(); // b → a would close the cycle
        }))
        .expect_err("cycle must panic");
        drop(gb);
        let msg = panic_message(err.as_ref());
        assert!(
            msg.contains("sync_test_cycle_a") && msg.contains("sync_test_cycle_b"),
            "panic must name both locks: {msg}"
        );
        assert!(msg.contains("cycle"), "panic must say why: {msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn same_class_nesting_panics() {
        let a = DebugMutex::new("sync_test_reentrant", 0u8);
        let b = DebugMutex::new("sync_test_reentrant", 0u8);
        let ga = a.lock();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gb = b.lock();
        }))
        .expect_err("same-class nesting must panic");
        drop(ga);
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("sync_test_reentrant"), "panic names the class: {msg}");
    }
}
