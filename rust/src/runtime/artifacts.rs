//! Artifact manifest: the menu of AOT-compiled tile shapes emitted by
//! `python/compile/aot.py` (`artifacts/manifest.txt`).
//!
//! The coordinator asks the manifest for the smallest artifact that
//! *covers* a requested shape (K ≥ k_needed, M ≥ m_needed); the gap is
//! closed with zero-padding + masking on the Rust side.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One artifact entry.
#[derive(Clone, Debug, PartialEq)]
pub enum ArtifactKind {
    /// Force tile with (B, K, D).
    Forces { b: usize, k: usize, d: usize },
    /// Flat-pair squared-distance tile with (T, M).
    Sqdist { t: usize, m: usize },
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

fn parse_kv(tok: &str, key: &str) -> Result<usize> {
    let Some(v) = tok.strip_prefix(&format!("{key}=")) else {
        bail!("expected {key}=<n>, got {tok:?}");
    };
    v.parse::<usize>().with_context(|| format!("bad {key} value {v:?}"))
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (one `kind name K=V...` line per artifact).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut specs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let err = || format!("manifest line {}: {line:?}", lineno + 1);
            if toks.len() < 2 {
                bail!("{} — too few tokens", err());
            }
            let kind = match toks[0] {
                "forces" => {
                    if toks.len() != 5 {
                        bail!("{} — want: forces name B= K= D=", err());
                    }
                    ArtifactKind::Forces {
                        b: parse_kv(toks[2], "B")?,
                        k: parse_kv(toks[3], "K")?,
                        d: parse_kv(toks[4], "D")?,
                    }
                }
                "sqdist" => {
                    if toks.len() != 4 {
                        bail!("{} — want: sqdist name T= M=", err());
                    }
                    ArtifactKind::Sqdist {
                        t: parse_kv(toks[2], "T")?,
                        m: parse_kv(toks[3], "M")?,
                    }
                }
                other => bail!("{} — unknown kind {other:?}", err()),
            };
            specs.push(ArtifactSpec {
                name: toks[1].to_string(),
                kind,
                path: dir.join(format!("{}.hlo.txt", toks[1])),
            });
        }
        if specs.is_empty() {
            bail!("manifest is empty");
        }
        Ok(Manifest { dir: dir.to_path_buf(), specs })
    }

    /// Smallest forces artifact with exact `d` and K ≥ `k_needed`.
    pub fn find_forces(&self, k_needed: usize, d: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| match s.kind {
                ArtifactKind::Forces { k, d: dd, .. } => dd == d && k >= k_needed,
                _ => false,
            })
            .min_by_key(|s| match s.kind {
                ArtifactKind::Forces { k, .. } => k,
                _ => usize::MAX,
            })
    }

    /// Smallest sqdist artifact with M ≥ `m_needed`.
    pub fn find_sqdist(&self, m_needed: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| match s.kind {
                ArtifactKind::Sqdist { m, .. } => m >= m_needed,
                _ => false,
            })
            .min_by_key(|s| match s.kind {
                ArtifactKind::Sqdist { m, .. } => m,
                _ => usize::MAX,
            })
    }

    /// All LD dims available for forces tiles (for error messages).
    pub fn forces_dims(&self) -> Vec<usize> {
        let mut dims: Vec<usize> = self
            .specs
            .iter()
            .filter_map(|s| match s.kind {
                ArtifactKind::Forces { d, .. } => Some(d),
                _ => None,
            })
            .collect();
        dims.sort_unstable();
        dims.dedup();
        dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
forces forces_b512_k8_d2 B=512 K=8 D=2
forces forces_b512_k32_d2 B=512 K=32 D=2
forces forces_b512_k16_d8 B=512 K=16 D=8
sqdist sqdist_t4096_m16 T=4096 M=16
sqdist sqdist_t4096_m64 T=4096 M=64
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.specs.len(), 5);
        assert_eq!(m.specs[0].kind, ArtifactKind::Forces { b: 512, k: 8, d: 2 });
        assert!(m.specs[3].path.ends_with("sqdist_t4096_m16.hlo.txt"));
    }

    #[test]
    fn find_forces_picks_smallest_covering_k() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let s = m.find_forces(8, 2).unwrap();
        assert_eq!(s.kind, ArtifactKind::Forces { b: 512, k: 8, d: 2 });
        let s = m.find_forces(9, 2).unwrap();
        assert_eq!(s.kind, ArtifactKind::Forces { b: 512, k: 32, d: 2 });
        assert!(m.find_forces(8, 5).is_none()); // no D=5 artifact
        assert!(m.find_forces(64, 2).is_none()); // K too large
    }

    #[test]
    fn find_sqdist_picks_smallest_covering_m() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.find_sqdist(10).unwrap().kind, ArtifactKind::Sqdist { t: 4096, m: 16 });
        assert_eq!(m.find_sqdist(17).unwrap().kind, ArtifactKind::Sqdist { t: 4096, m: 64 });
        assert!(m.find_sqdist(100).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse(Path::new("/t"), "forces x B=1").is_err());
        assert!(Manifest::parse(Path::new("/t"), "weird x Y=1").is_err());
        assert!(Manifest::parse(Path::new("/t"), "").is_err());
        assert!(Manifest::parse(Path::new("/t"), "forces x B=a K=2 D=3").is_err());
    }

    #[test]
    fn forces_dims_lists_unique_sorted() {
        let m = Manifest::parse(Path::new("/t"), SAMPLE).unwrap();
        assert_eq!(m.forces_dims(), vec![2, 8]);
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // Runs against the actual artifacts/ when built (skips otherwise).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find_forces(32, 2).is_some());
            assert!(m.find_sqdist(64).is_some());
            for s in &m.specs {
                assert!(s.path.exists(), "missing artifact file {:?}", s.path);
            }
        }
    }
}
