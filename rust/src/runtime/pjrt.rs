//! PJRT execution of AOT artifacts (the `xla` crate / PJRT C API).
//!
//! One [`PjrtRuntime`] per process: a CPU PJRT client plus a cache of
//! compiled executables keyed by artifact name. HLO *text* is the
//! interchange format (see /opt/xla-example/README.md: jax ≥ 0.5 protos
//! carry 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns them).

use super::artifacts::{ArtifactKind, ArtifactSpec, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Runtime state: client + compiled executables.
///
/// Both maps are `BTreeMap` on purpose: `exec_counts` feeds telemetry
/// output, and sorted iteration keeps that output byte-identical run
/// over run (a `HashMap` would shuffle it per process).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Telemetry: executions per artifact (perf accounting).
    pub exec_counts: BTreeMap<String, u64>,
}

fn f32s_as_bytes(xs: &[f32]) -> &[u8] {
    // SAFETY: any &[f32] is valid to view as bytes — f32 has no
    // padding and every bit pattern is a valid u8; the pointer and
    // length describe exactly the slice's own allocation.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// Build an f32 literal of the given dims from a host slice.
fn literal_f32(xs: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let expect: usize = dims.iter().product();
    if expect != xs.len() {
        bail!("literal shape {:?} != data len {}", dims, xs.len());
    }
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        f32s_as_bytes(xs),
    )
    .map_err(|e| anyhow!("literal creation failed: {e:?}"))
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and load the manifest (artifacts are
    /// compiled lazily on first use).
    pub fn new(artifact_dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            exes: BTreeMap::new(),
            exec_counts: BTreeMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the executable for an artifact.
    fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(&spec.name) {
            let path_str = spec
                .path
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.path))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| anyhow!("parse HLO {:?}: {e:?}", spec.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
            self.exes.insert(spec.name.clone(), exe);
        }
        Ok(&self.exes[&spec.name])
    }

    /// Pre-compile the artifacts an embedding run will need (so the
    /// first iteration isn't slowed by compilation).
    pub fn warmup(&mut self, k_hd: usize, k_ld: usize, n_neg: usize, d: usize, m: usize) -> Result<()> {
        let mut names = Vec::new();
        for k in [k_hd, k_ld, n_neg] {
            if k == 0 {
                continue;
            }
            let spec = self.manifest.find_forces(k, d).cloned().with_context(|| {
                format!(
                    "no forces artifact for K>={k}, D={d}; available dims {:?} — \
                     regenerate with python/compile/aot.py or use --backend native",
                    self.manifest.forces_dims()
                )
            })?;
            names.push(spec);
        }
        let sq = self
            .manifest
            .find_sqdist(m)
            .cloned()
            .with_context(|| format!("no sqdist artifact for M>={m}"))?;
        names.push(sq);
        for spec in names {
            self.executable(&spec)?;
        }
        Ok(())
    }

    /// Execute a forces tile: inputs already padded to the artifact's
    /// (B, K, D). Returns (attr B·D, rep B·D, wsum B).
    #[allow(clippy::too_many_arguments)]
    pub fn exec_forces(
        &mut self,
        spec: &ArtifactSpec,
        alpha: f32,
        yi: &[f32],
        yj: &[f32],
        p: &[f32],
        mask: &[f32],
        attr_out: &mut [f32],
        rep_out: &mut [f32],
        wsum_out: &mut [f32],
    ) -> Result<()> {
        let ArtifactKind::Forces { b, k, d } = spec.kind else {
            bail!("{} is not a forces artifact", spec.name);
        };
        let args = [
            literal_f32(&[alpha], &[1])?,
            literal_f32(yi, &[b, d])?,
            literal_f32(yj, &[b, k, d])?,
            literal_f32(p, &[b, k])?,
            literal_f32(mask, &[b, k])?,
        ];
        let exe = self.executable(spec)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let (attr, rep, wsum) = result
            .to_tuple3()
            .map_err(|e| anyhow!("expected 3-tuple output: {e:?}"))?;
        attr.copy_raw_to(attr_out).map_err(|e| anyhow!("attr copy: {e:?}"))?;
        rep.copy_raw_to(rep_out).map_err(|e| anyhow!("rep copy: {e:?}"))?;
        wsum.copy_raw_to(wsum_out).map_err(|e| anyhow!("wsum copy: {e:?}"))?;
        *self.exec_counts.entry(spec.name.clone()).or_insert(0) += 1;
        Ok(())
    }

    /// Execute a sqdist tile: `a`, `b` padded to (T, M); output T dists.
    pub fn exec_sqdist(
        &mut self,
        spec: &ArtifactSpec,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let ArtifactKind::Sqdist { t, m } = spec.kind else {
            bail!("{} is not a sqdist artifact", spec.name);
        };
        let args = [literal_f32(a, &[t, m])?, literal_f32(b, &[t, m])?];
        let exe = self.executable(spec)?;
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let d2 = result
            .to_tuple1()
            .map_err(|e| anyhow!("expected 1-tuple output: {e:?}"))?;
        d2.copy_raw_to(out).map_err(|e| anyhow!("dist copy: {e:?}"))?;
        *self.exec_counts.entry(spec.name.clone()).or_insert(0) += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.txt").exists()
    }

    #[test]
    fn sqdist_artifact_executes_correctly() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = PjrtRuntime::new(&artifact_dir()).unwrap();
        let spec = rt.manifest.find_sqdist(8).unwrap().clone();
        let ArtifactKind::Sqdist { t, m } = spec.kind else { unreachable!() };
        let mut a = vec![0.0f32; t * m];
        let mut b = vec![0.0f32; t * m];
        // pair 0: distance² = 4 (2 along first axis); pair 1: 2.
        a[0] = 2.0;
        b[t.min(1) * m] = 1.0;
        b[t.min(1) * m + 1] = 1.0;
        let mut out = vec![0.0f32; t];
        rt.exec_sqdist(&spec, &a, &b, &mut out).unwrap();
        assert!((out[0] - 4.0).abs() < 1e-6, "{}", out[0]);
        assert!((out[1] - 2.0).abs() < 1e-6, "{}", out[1]);
        assert!(out[2..].iter().all(|&v| v.abs() < 1e-9));
    }

    #[test]
    fn forces_artifact_matches_native_math() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = PjrtRuntime::new(&artifact_dir()).unwrap();
        let spec = rt.manifest.find_forces(8, 2).unwrap().clone();
        let ArtifactKind::Forces { b, k, d } = spec.kind else { unreachable!() };
        let mut rng = crate::util::Rng::new(5);
        let yi: Vec<f32> = (0..b * d).map(|_| rng.gauss() as f32).collect();
        let yj: Vec<f32> = (0..b * k * d).map(|_| rng.gauss() as f32).collect();
        let p: Vec<f32> = (0..b * k).map(|_| rng.f32() * 0.1).collect();
        let mask: Vec<f32> = (0..b * k).map(|_| if rng.chance(0.7) { 1.0 } else { 0.0 }).collect();
        let alpha = 0.7f32;
        let (mut attr, mut rep, mut wsum) =
            (vec![0.0f32; b * d], vec![0.0f32; b * d], vec![0.0f32; b]);
        rt.exec_forces(&spec, alpha, &yi, &yj, &p, &mask, &mut attr, &mut rep, &mut wsum)
            .unwrap();
        // Scalar re-computation of the same math.
        for i in 0..b.min(64) {
            let (mut ea, mut er) = (vec![0.0f32; d], vec![0.0f32; d]);
            let mut ew = 0.0f32;
            for s in 0..k {
                if mask[i * k + s] == 0.0 {
                    continue;
                }
                let mut d2 = 0.0f32;
                for c in 0..d {
                    let diff = yj[(i * k + s) * d + c] - yi[i * d + c];
                    d2 += diff * diff;
                }
                let g = 1.0 / (1.0 + d2 / alpha);
                let w = g.powf(alpha);
                ew += w;
                for c in 0..d {
                    let diff = yj[(i * k + s) * d + c] - yi[i * d + c];
                    ea[c] += p[i * k + s] * g * diff;
                    er[c] += w * g * (-diff);
                }
            }
            for c in 0..d {
                assert!(
                    (attr[i * d + c] - ea[c]).abs() < 1e-4,
                    "attr[{i},{c}]: {} vs {}",
                    attr[i * d + c],
                    ea[c]
                );
                assert!(
                    (rep[i * d + c] - er[c]).abs() < 1e-4,
                    "rep[{i},{c}]: {} vs {}",
                    rep[i * d + c],
                    er[c]
                );
            }
            assert!((wsum[i] - ew).abs() < 1e-4);
        }
        assert_eq!(rt.exec_counts[&spec.name], 1);
    }
}
