//! A zero-dependency worker pool built on `std::thread::scope`.
//!
//! The force decomposition isolates per-point accumulation (backends are
//! deterministic given their arguments), so the hot passes shard cleanly
//! by contiguous index ranges: each shard owns a disjoint slice of the
//! output and no synchronisation is needed beyond the fork/join itself.
//! Scoped threads let shards borrow the engine's matrices and tables
//! directly — no `Arc`, no channels, no `'static` bounds.
//!
//! Spawning is per call (a scoped thread costs tens of microseconds),
//! which is negligible against a multi-millisecond force pass over tens
//! of thousands of points; a persistent pool would save nothing
//! measurable and would force `Send` bounds through the backend
//! boundary.

use std::ops::Range;

/// Split `[0, len)` into at most `shards` contiguous ranges whose sizes
/// differ by at most one. Returns fewer ranges when `len < shards`;
/// always returns at least one (possibly empty) range.
pub fn shard_ranges(len: usize, shards: usize) -> Vec<Range<usize>> {
    let shards = shards.max(1).min(len.max(1));
    let base = len / shards;
    let rem = len % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let size = base + usize::from(s < rem);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// A fixed-width fork/join helper: runs closures on scoped threads.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool that runs up to `threads` tasks concurrently (minimum 1).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    /// Resolve `threads = 0` to the machine's available parallelism.
    pub fn with_auto(threads: usize) -> WorkerPool {
        if threads == 0 {
            WorkerPool::new(available_threads())
        } else {
            WorkerPool::new(threads)
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion, one scoped thread per task, and
    /// return their results in task order. A single task (the common
    /// `threads = 1` configuration) runs inline on the caller's thread.
    ///
    /// The `'a` lifetime ties the tasks' borrows to the caller: scoped
    /// threads join before this returns, so tasks may freely borrow
    /// caller-owned data (including disjoint `&mut` output chunks).
    ///
    /// Panics propagate: a panicking worker aborts the join with the
    /// worker's panic payload rather than deadlocking or silently
    /// dropping a shard.
    pub fn run_tasks<'a, R, T>(&self, tasks: Vec<T>) -> Vec<R>
    where
        R: Send + 'a,
        T: FnOnce() -> R + Send + 'a,
    {
        if tasks.len() <= 1 {
            return tasks.into_iter().map(|t| t()).collect();
        }
        std::thread::scope(|scope| {
            let handles: Vec<_> = tasks.into_iter().map(|t| scope.spawn(t)).collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(payload) => std::panic::resume_unwind(payload),
                })
                .collect()
        })
    }
}

/// The machine's available parallelism (1 if it cannot be queried).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly() {
        let cases = [(0usize, 4usize), (1, 4), (7, 3), (8, 3), (100, 7), (5, 1), (3, 8)];
        for &(len, shards) in &cases {
            let ranges = shard_ranges(len, shards);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= shards.max(1));
            // Contiguous, disjoint, covering [0, len).
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, len, "len={len} shards={shards}");
            // Balanced: sizes differ by at most one.
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced {sizes:?}");
        }
    }

    #[test]
    fn run_tasks_returns_in_task_order() {
        // The canonical sharding pattern: shard_ranges + one task per
        // range, partial results reduced in shard order at the join.
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let tasks: Vec<_> = shard_ranges(data.len(), pool.threads())
            .into_iter()
            .enumerate()
            .map(|(s, range)| {
                let data = &data;
                move || (s, data[range].iter().sum::<u64>())
            })
            .collect();
        let partials = pool.run_tasks(tasks);
        assert_eq!(partials.len(), 4);
        for (expect_s, (s, _)) in partials.iter().enumerate() {
            assert_eq!(expect_s, *s);
        }
        let total: u64 = partials.iter().map(|(_, p)| p).sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn run_tasks_single_runs_inline() {
        // One task must not spawn: verify it runs on the calling thread.
        let caller = std::thread::current().id();
        let pool = WorkerPool::new(8);
        let ids = pool.run_tasks(vec![move || std::thread::current().id()]);
        assert_eq!(ids[0], caller);
    }

    #[test]
    fn run_tasks_borrows_disjoint_mut_slices() {
        // The pattern the parallel backend relies on: each task owns a
        // disjoint &mut chunk of one output buffer.
        let pool = WorkerPool::new(3);
        let mut out = vec![0u32; 9];
        let mut tasks = Vec::new();
        let mut rest = out.as_mut_slice();
        for s in 0..3u32 {
            let (head, tail) = rest.split_at_mut(3);
            rest = tail;
            tasks.push(move || {
                for v in head.iter_mut() {
                    *v = s + 1;
                }
                s
            });
        }
        let done = pool.run_tasks(tasks);
        assert_eq!(done, vec![0, 1, 2]);
        assert_eq!(out, vec![1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn pool_width_clamps_to_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
        assert!(WorkerPool::with_auto(0).threads() >= 1);
    }
}
