//! A zero-dependency worker pool built on `std::thread::scope`.
//!
//! Every hot pass of an iteration shards cleanly by contiguous index
//! ranges: the force, update and scoring passes because per-point
//! accumulation is isolated (backends are deterministic given their
//! arguments), and the KNN-refinement and negative-sampling passes
//! because their randomness comes from counter-based
//! [`crate::util::StreamRng`] streams — a per-point pure function, so
//! no shard ever waits on another's RNG cursor. Each shard owns a
//! disjoint slice of the output (or a disjoint row view of a
//! neighbour table) and no synchronisation is needed beyond the
//! fork/join itself. Cross-row writes that cannot be made disjoint
//! (symmetric neighbour inserts) are buffered per shard and applied on
//! the calling thread in fixed shard-then-point order — so the result
//! is bitwise thread-count-invariant by construction. Scoped threads
//! let shards borrow the engine's matrices and tables directly — no
//! `Arc`, no channels, no `'static` bounds.
//!
//! Spawning is per call (a scoped thread costs tens of microseconds),
//! which is negligible against a multi-millisecond pass over tens of
//! thousands of points and is gated by per-shard work floors on every
//! call site (small inputs run inline); a persistent pool would save
//! nothing measurable and would force `Send` bounds through the
//! backend boundary.

use std::ops::Range;

/// Shards to actually use for `len` items under a per-shard work
/// floor: below `min_per_shard` items per extra shard the scoped-thread
/// fork/join costs more than the compute it buys, so the call falls
/// back to fewer shards — possibly one (inline on the caller's
/// thread). Purely a wall-clock knob: every sharded pass in this repo
/// is bitwise partition-invariant by construction, so the floor never
/// changes an output bit. This is THE floor formula — call sites must
/// not reimplement it, or their fallback policies silently diverge.
pub fn effective_shards(pool: &WorkerPool, len: usize, min_per_shard: usize) -> usize {
    pool.threads().min(len / min_per_shard.max(1)).max(1)
}

/// Split `slice` into disjoint mutable chunks matching `ranges`
/// (ascending, non-overlapping index ranges; gaps are skipped), each
/// index spanning `width` elements. The sharded passes use this to
/// hand each worker the sub-slice matching its point range — the
/// borrow checker proves disjointness, so no synchronisation is needed.
pub fn split_by_ranges<'a, T>(
    slice: &'a mut [T],
    ranges: &[Range<usize>],
    width: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut rest = slice;
    let mut consumed = 0usize;
    for r in ranges {
        assert!(
            r.start >= consumed && r.start <= r.end,
            "split_by_ranges: bad range {r:?} (consumed {consumed})"
        );
        let (_, tail) = rest.split_at_mut((r.start - consumed) * width);
        let (head, tail) = tail.split_at_mut((r.end - r.start) * width);
        out.push(head);
        rest = tail;
        consumed = r.end;
    }
    out
}

/// Split `[0, len)` into at most `shards` contiguous ranges whose sizes
/// differ by at most one. Returns fewer ranges when `len < shards`;
/// always returns at least one (possibly empty) range.
pub fn shard_ranges(len: usize, shards: usize) -> Vec<Range<usize>> {
    let shards = shards.max(1).min(len.max(1));
    let base = len / shards;
    let rem = len % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let size = base + usize::from(s < rem);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// A fixed-width fork/join helper: runs closures on scoped threads.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool that runs up to `threads` tasks concurrently (minimum 1).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    /// Resolve `threads = 0` to the machine's available parallelism.
    pub fn with_auto(threads: usize) -> WorkerPool {
        if threads == 0 {
            WorkerPool::new(available_threads())
        } else {
            WorkerPool::new(threads)
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion, one scoped thread per task, and
    /// return their results in task order. A single task (the common
    /// `threads = 1` configuration) runs inline on the caller's thread.
    ///
    /// The `'a` lifetime ties the tasks' borrows to the caller: scoped
    /// threads join before this returns, so tasks may freely borrow
    /// caller-owned data (including disjoint `&mut` output chunks).
    ///
    /// Panics propagate: a panicking worker aborts the join with the
    /// worker's panic payload rather than deadlocking or silently
    /// dropping a shard.
    pub fn run_tasks<'a, R, T>(&self, tasks: Vec<T>) -> Vec<R>
    where
        R: Send + 'a,
        T: FnOnce() -> R + Send + 'a,
    {
        if tasks.len() <= 1 {
            return tasks.into_iter().map(|t| t()).collect();
        }
        std::thread::scope(|scope| {
            let handles: Vec<_> = tasks.into_iter().map(|t| scope.spawn(t)).collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(payload) => std::panic::resume_unwind(payload),
                })
                .collect()
        })
    }
}

/// The machine's available parallelism (1 if it cannot be queried).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly() {
        let cases = [(0usize, 4usize), (1, 4), (7, 3), (8, 3), (100, 7), (5, 1), (3, 8)];
        for &(len, shards) in &cases {
            let ranges = shard_ranges(len, shards);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= shards.max(1));
            // Contiguous, disjoint, covering [0, len).
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, len, "len={len} shards={shards}");
            // Balanced: sizes differ by at most one.
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced {sizes:?}");
        }
    }

    #[test]
    fn run_tasks_returns_in_task_order() {
        // The canonical sharding pattern: shard_ranges + one task per
        // range, partial results reduced in shard order at the join.
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let tasks: Vec<_> = shard_ranges(data.len(), pool.threads())
            .into_iter()
            .enumerate()
            .map(|(s, range)| {
                let data = &data;
                move || (s, data[range].iter().sum::<u64>())
            })
            .collect();
        let partials = pool.run_tasks(tasks);
        assert_eq!(partials.len(), 4);
        for (expect_s, (s, _)) in partials.iter().enumerate() {
            assert_eq!(expect_s, *s);
        }
        let total: u64 = partials.iter().map(|(_, p)| p).sum();
        assert_eq!(total, 1000 * 999 / 2);
    }

    #[test]
    fn run_tasks_single_runs_inline() {
        // One task must not spawn: verify it runs on the calling thread.
        let caller = std::thread::current().id();
        let pool = WorkerPool::new(8);
        let ids = pool.run_tasks(vec![move || std::thread::current().id()]);
        assert_eq!(ids[0], caller);
    }

    #[test]
    fn run_tasks_borrows_disjoint_mut_slices() {
        // The pattern the parallel backend relies on: each task owns a
        // disjoint &mut chunk of one output buffer.
        let pool = WorkerPool::new(3);
        let mut out = vec![0u32; 9];
        let mut tasks = Vec::new();
        let mut rest = out.as_mut_slice();
        for s in 0..3u32 {
            let (head, tail) = rest.split_at_mut(3);
            rest = tail;
            tasks.push(move || {
                for v in head.iter_mut() {
                    *v = s + 1;
                }
                s
            });
        }
        let done = pool.run_tasks(tasks);
        assert_eq!(done, vec![0, 1, 2]);
        assert_eq!(out, vec![1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn pool_width_clamps_to_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
        assert!(WorkerPool::with_auto(0).threads() >= 1);
    }

    #[test]
    fn effective_shards_honours_floor_and_width() {
        let pool = WorkerPool::new(4);
        assert_eq!(effective_shards(&pool, 1000, 256), 3); // floor-bound
        assert_eq!(effective_shards(&pool, 100_000, 256), 4); // width-bound
        assert_eq!(effective_shards(&pool, 10, 256), 1); // tiny input inline
        assert_eq!(effective_shards(&pool, 0, 256), 1);
        assert_eq!(effective_shards(&pool, 10, 0), 4, "zero floor must not divide by zero");
    }

    #[test]
    fn split_by_ranges_matches_ranges_with_width_and_gaps() {
        let mut data: Vec<u32> = (0..20).collect();
        let chunks = split_by_ranges(&mut data, &[0..2, 3..5], 2);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].to_vec(), vec![0, 1, 2, 3]); // indices 0..2 at width 2
        assert_eq!(chunks[1].to_vec(), vec![6, 7, 8, 9]); // gap (index 2) skipped
        chunks.into_iter().flatten().for_each(|v| *v = 99);
        assert_eq!(data[4], 4, "gap untouched");
        assert_eq!(data[0], 99);
    }

    #[test]
    #[should_panic(expected = "bad range")]
    fn split_by_ranges_rejects_overlap() {
        let mut data = vec![0u8; 10];
        let _ = split_by_ranges(&mut data, &[0..4, 2..6], 1);
    }
}
