//! Self-hosted static analysis: the determinism & concurrency linter
//! behind the `funcsne lint` subcommand and the CI `lint` gate.
//!
//! The crate's central correctness claim — bitwise thread-count-
//! invariant trajectories, which every golden parity test relies on —
//! is easy to break silently: one `Instant::now()` in the engine, one
//! iterated `HashMap` in a sharded pass, one unranked `Mutex` next to
//! the FrameHub. This module machine-checks those conventions on every
//! CI run instead of leaving them to review.
//!
//! Pipeline: [`scanner`] tokenizes each `.rs` file (comment-, string-
//! and raw-string-aware, with a `#[cfg(test)]` mask), [`rules`] runs
//! six token-level rules over the scan, and [`config`] applies
//! per-rule waivers from the repo-root `lint.toml`. Everything is
//! `std`-only and deterministic: files walk in sorted order and
//! findings sort by (path, line, rule).
//!
//! The rules (see `docs/determinism.md` for the full rationale):
//!
//! 1. `wall_clock` — no `Instant`/`SystemTime` in deterministic modules
//! 2. `hash_collections` — no `HashMap`/`HashSet` in deterministic modules
//! 3. `safety_comment` — every `unsafe` carries a `// SAFETY:` line
//! 4. `raw_sync` — no raw `std::sync` locks outside `runtime/sync.rs`
//! 5. `server_panics` — no `.unwrap()`/`.expect("...")` on request paths
//! 6. `f32_reduction` — no f32 `.sum()`/unordered `.fold()` in sharded code

pub mod config;
pub mod rules;
pub mod scanner;

pub use config::LintConfig;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One rule violation at a source location.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based source line.
    pub line: u32,
    /// Rule name (one of [`rules::RULE_NAMES`]).
    pub rule: &'static str,
    /// Human-readable explanation with the suggested fix.
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// The result of linting a tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Violations that survived the allowlist, sorted by
    /// (path, line, rule).
    pub findings: Vec<Finding>,
    /// `.rs` files scanned.
    pub files_scanned: usize,
    /// Findings suppressed by `lint.toml` waivers.
    pub waived: usize,
}

/// Lint one source text as if it lived at `rel_path` under the root.
/// Returns surviving findings plus the number waived by `cfg`.
pub fn lint_source(rel_path: &str, text: &str, cfg: &LintConfig) -> (Vec<Finding>, usize) {
    let scan = scanner::scan(text);
    let raw = rules::check(rel_path, &scan);
    let before = raw.len();
    let kept: Vec<Finding> =
        raw.into_iter().filter(|f| cfg.waiver(f.rule, &f.path).is_none()).collect();
    let waived = before - kept.len();
    (kept, waived)
}

/// Lint every `.rs` file under `src_root` (recursively, sorted order).
pub fn lint_tree(src_root: &Path, cfg: &LintConfig) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)
        .with_context(|| format!("walk source tree {src_root:?}"))?;
    files.sort();
    let mut report = LintReport::default();
    for file in &files {
        let rel = file
            .strip_prefix(src_root)
            .unwrap_or(file)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text =
            std::fs::read_to_string(file).with_context(|| format!("read source {file:?}"))?;
        let (mut findings, waived) = lint_source(&rel, &text, cfg);
        report.findings.append(&mut findings);
        report.waived += waived;
        report.files_scanned += 1;
    }
    report.findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("read_dir {dir:?}"))?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_flagged_only_in_deterministic_scope() {
        let src = "use std::time::Instant;\nfn f() { let t = Instant::now(); }\n";
        let cfg = LintConfig::empty();
        let (in_engine, _) = lint_source("engine/funcsne.rs", src, &cfg);
        assert_eq!(in_engine.len(), 2, "{in_engine:?}");
        assert!(in_engine.iter().all(|f| f.rule == rules::WALL_CLOCK));
        let (in_bench, _) = lint_source("util/timer.rs", src, &cfg);
        assert!(in_bench.is_empty(), "timer shim may read the clock");
    }

    #[test]
    fn waiver_suppresses_and_counts() {
        let src = "fn f() { let s = std::collections::HashSet::new(); }\n";
        let cfg = LintConfig::from_text(
            "[allow.hash_collections]\nknn/a.rs = \"membership only\"\n",
        )
        .unwrap();
        let (kept, waived) = lint_source("knn/a.rs", src, &cfg);
        assert!(kept.is_empty());
        assert_eq!(waived, 1);
        let (kept_other, _) = lint_source("knn/b.rs", src, &cfg);
        assert_eq!(kept_other.len(), 1, "waiver is per-path");
    }

    #[test]
    fn findings_name_file_line_and_rule() {
        let src = "fn f() {\n    let m = Mutex::new(0);\n}\n";
        let (findings, _) = lint_source("server/x.rs", src, &LintConfig::empty());
        assert_eq!(findings.len(), 1);
        let text = findings[0].to_string();
        assert!(text.contains("server/x.rs:2"), "{text}");
        assert!(text.contains("raw_sync"), "{text}");
    }

    #[test]
    fn test_code_is_exempt_from_production_rules() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let x: f32 = v.iter().sum(); }\n}\n";
        let (findings, _) = lint_source("ld/a.rs", src, &LintConfig::empty());
        assert!(findings.is_empty(), "{findings:?}");
    }
}
