//! A comment/string/raw-string-aware token scanner over Rust source.
//!
//! The lint rules need exactly three views of a file, all cheap to
//! build in one pass and none requiring a real parser:
//!
//! * the **token stream** (identifiers, literals, punctuation) with
//!   comments and string/char contents stripped, so `"Instant"` inside
//!   a string literal or a doc comment never trips a rule;
//! * the **comment map** (line → comment text), so the `SAFETY:` rule
//!   can look at the prose immediately above an `unsafe` token;
//! * the **test mask** (per-token: is this inside a `#[cfg(test)]`
//!   item?), so rules that only police production code can skip test
//!   modules without path heuristics.
//!
//! Handled literal forms: `//` and nested `/* */` comments, `"…"`
//! strings with escapes (including multi-line), raw strings
//! `r"…"`/`r#"…"#` with any hash depth, byte strings `b"…"`/`br#"…"#`,
//! char and byte-char literals (`'a'`, `'\n'`, `b'{'`), and lifetimes
//! (`'a`, `'static`), which look like unterminated chars to a naive
//! scanner.

use std::collections::{BTreeMap, BTreeSet};

/// What kind of lexeme a [`Token`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal, suffix included (`1.5e-3`, `0u64`, `1.0f32`).
    Num,
    /// String literal of any flavour (contents discarded).
    Str,
    /// Char or byte-char literal (contents discarded).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Single punctuation character.
    Punct,
}

/// One lexeme with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub line: u32,
    pub kind: TokenKind,
    pub text: String,
}

/// The scanner's output: tokens plus the comment/code line indexes the
/// rules consult.
pub struct Scan {
    pub tokens: Vec<Token>,
    /// 1-based line → concatenated comment text appearing on it.
    pub comment_lines: BTreeMap<u32, String>,
    /// 1-based lines that carry at least one token (code lines).
    pub code_lines: BTreeSet<u32>,
    /// Per-token: lies inside an item annotated `#[cfg(test)]` (or any
    /// `cfg(...)` whose argument list mentions `test`).
    pub in_test: Vec<bool>,
}

impl Scan {
    /// Comment text on `line`, if any.
    pub fn comment_on(&self, line: u32) -> Option<&str> {
        self.comment_lines.get(&line).map(String::as_str)
    }
}

/// Tokenize `text` and build the comment/code indexes plus the
/// `#[cfg(test)]` mask.
pub fn scan(text: &str) -> Scan {
    let cs: Vec<char> = text.chars().collect();
    let mut tokens = Vec::new();
    let mut comment_lines: BTreeMap<u32, String> = BTreeMap::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers /// and //! doc comments).
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i;
            while i < cs.len() && cs[i] != '\n' {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            comment_lines.entry(line).or_default().push_str(&text);
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            let mut buf = String::new();
            i += 2;
            while i < cs.len() && depth > 0 {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    buf.push_str("/*");
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else if cs[i] == '\n' {
                    comment_lines.entry(line).or_default().push_str(&buf);
                    buf.clear();
                    line += 1;
                    i += 1;
                } else {
                    buf.push(cs[i]);
                    i += 1;
                }
            }
            comment_lines.entry(line).or_default().push_str(&buf);
            continue;
        }
        // Plain string literal (may span lines).
        if c == '"' {
            i += 1;
            skip_string_body(&cs, &mut i, &mut line);
            tokens.push(Token { line, kind: TokenKind::Str, text: String::new() });
            continue;
        }
        // Identifier, keyword, or a prefixed literal (r"", br#""#, b"", b'').
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < cs.len() && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            let word: String = cs[start..i].iter().collect();
            let next = cs.get(i).copied();
            if (word == "r" || word == "br") && matches!(next, Some('"') | Some('#')) {
                if skip_raw_string(&cs, &mut i, &mut line) {
                    tokens.push(Token { line, kind: TokenKind::Str, text: String::new() });
                } else {
                    // `r#ident` raw identifier, not a raw string.
                    tokens.push(Token { line, kind: TokenKind::Ident, text: word });
                }
                continue;
            }
            if word == "b" && next == Some('"') {
                i += 1;
                skip_string_body(&cs, &mut i, &mut line);
                tokens.push(Token { line, kind: TokenKind::Str, text: String::new() });
                continue;
            }
            if word == "b" && next == Some('\'') {
                i += 1;
                skip_char_body(&cs, &mut i);
                tokens.push(Token { line, kind: TokenKind::Char, text: String::new() });
                continue;
            }
            tokens.push(Token { line, kind: TokenKind::Ident, text: word });
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            let is_lifetime = cs
                .get(i + 1)
                .is_some_and(|&n| n.is_alphabetic() || n == '_')
                && cs.get(i + 2) != Some(&'\'');
            if is_lifetime {
                let start = i + 1;
                i += 1;
                while i < cs.len() && (cs[i].is_alphanumeric() || cs[i] == '_') {
                    i += 1;
                }
                let text: String = cs[start..i].iter().collect();
                tokens.push(Token { line, kind: TokenKind::Lifetime, text });
            } else {
                i += 1;
                skip_char_body(&cs, &mut i);
                tokens.push(Token { line, kind: TokenKind::Char, text: String::new() });
            }
            continue;
        }
        // Numeric literal, suffix included.
        if c.is_ascii_digit() {
            let start = i;
            while i < cs.len() {
                let d = cs[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' && cs.get(i + 1).is_some_and(|n| n.is_ascii_digit()) {
                    i += 1;
                } else if (d == '+' || d == '-')
                    && matches!(cs.get(i.wrapping_sub(1)), Some('e') | Some('E'))
                    && cs.get(i + 1).is_some_and(|n| n.is_ascii_digit())
                {
                    i += 1;
                } else {
                    break;
                }
            }
            let text: String = cs[start..i].iter().collect();
            tokens.push(Token { line, kind: TokenKind::Num, text });
            continue;
        }
        tokens.push(Token { line, kind: TokenKind::Punct, text: c.to_string() });
        i += 1;
    }
    let code_lines: BTreeSet<u32> = tokens.iter().map(|t| t.line).collect();
    let in_test = test_mask(&tokens);
    Scan { tokens, comment_lines, code_lines, in_test }
}

/// Consume a (possibly multi-line) string body; `i` starts just past
/// the opening quote and ends just past the closing one.
fn skip_string_body(cs: &[char], i: &mut usize, line: &mut u32) {
    while *i < cs.len() {
        match cs[*i] {
            '\\' => *i += 2,
            '"' => {
                *i += 1;
                return;
            }
            '\n' => {
                *line += 1;
                *i += 1;
            }
            _ => *i += 1,
        }
    }
}

/// Consume a char/byte-char body; `i` starts just past the opening
/// quote. Escapes (`'\n'`, `'\u{1F600}'`, `'\''`) are handled.
fn skip_char_body(cs: &[char], i: &mut usize) {
    while *i < cs.len() {
        match cs[*i] {
            '\\' => *i += 2,
            '\'' => {
                *i += 1;
                return;
            }
            _ => *i += 1,
        }
    }
}

/// Consume a raw (byte) string starting at `i` (positioned on the `"`
/// or first `#` after the `r`/`br` prefix). Returns false — consuming
/// nothing — when this is a raw identifier (`r#match`) rather than a
/// raw string.
fn skip_raw_string(cs: &[char], i: &mut usize, line: &mut u32) -> bool {
    let mut j = *i;
    let mut hashes = 0usize;
    while cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if cs.get(j) != Some(&'"') {
        return false;
    }
    j += 1;
    while j < cs.len() {
        if cs[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if cs[j] == '"' && cs[j + 1..].iter().take(hashes).filter(|&&h| h == '#').count() == hashes
        {
            *i = j + 1 + hashes;
            return true;
        }
        j += 1;
    }
    *i = j;
    true
}

fn is_punct(tokens: &[Token], i: usize, c: char) -> bool {
    tokens
        .get(i)
        .is_some_and(|t| t.kind == TokenKind::Punct && t.text.len() == 1 && t.text.starts_with(c))
}

fn is_ident(tokens: &[Token], i: usize, word: &str) -> bool {
    tokens.get(i).is_some_and(|t| t.kind == TokenKind::Ident && t.text == word)
}

/// Index of the punct closing the group opened at `open` (which must
/// hold `open_c`), or `tokens.len()` when unbalanced.
fn match_group(tokens: &[Token], open: usize, open_c: char, close_c: char) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < tokens.len() {
        if is_punct(tokens, i, open_c) {
            depth += 1;
        } else if is_punct(tokens, i, close_c) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    tokens.len()
}

/// Mark every token belonging to an item annotated with a `cfg`
/// attribute that mentions `test` — `#[cfg(test)]` and compositions
/// like `#[cfg(all(test, unix))]` alike. The item body is found by
/// brace/semicolon matching, which tokenized input makes reliable
/// (braces inside strings or comments were already discarded).
fn test_mask(tokens: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if is_punct(tokens, i, '#')
            && is_punct(tokens, i + 1, '[')
            && is_ident(tokens, i + 2, "cfg")
            && is_punct(tokens, i + 3, '(')
        {
            let close = match_group(tokens, i + 3, '(', ')');
            let mentions_test = tokens[(i + 4).min(close)..close.min(tokens.len())]
                .iter()
                .any(|t| t.kind == TokenKind::Ident && t.text == "test");
            if mentions_test && is_punct(tokens, close + 1, ']') {
                // Skip any further attributes between the cfg and the
                // item it gates (`#[cfg(test)] #[allow(...)] mod t {}`).
                let mut j = close + 2;
                while is_punct(tokens, j, '#') && is_punct(tokens, j + 1, '[') {
                    j = match_group(tokens, j + 1, '[', ']') + 1;
                }
                let end = item_end(tokens, j);
                let last = end.min(tokens.len().saturating_sub(1));
                for m in &mut mask[i..=last] {
                    *m = true;
                }
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    mask
}

/// Find where the item starting at `from` ends: the matching `}` of its
/// body, or the `;` of a body-less item, skipping balanced `(`/`[`
/// groups in the signature on the way.
fn item_end(tokens: &[Token], from: usize) -> usize {
    let mut depth = 0i64;
    let mut i = from;
    while i < tokens.len() {
        if is_punct(tokens, i, '(') || is_punct(tokens, i, '[') {
            depth += 1;
        } else if is_punct(tokens, i, ')') || is_punct(tokens, i, ']') {
            depth -= 1;
        } else if is_punct(tokens, i, '{') && depth == 0 {
            return match_group(tokens, i, '{', '}');
        } else if is_punct(tokens, i, '{') {
            depth += 1;
        } else if is_punct(tokens, i, '}') {
            depth -= 1;
        } else if is_punct(tokens, i, ';') && depth == 0 {
            return i;
        }
        i += 1;
    }
    tokens.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(scan: &Scan) -> Vec<&str> {
        scan.tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn strings_and_comments_hide_identifiers() {
        let src = "let a = \"Instant::now()\"; // Instant here too\nlet b = 1;";
        let s = scan(src);
        assert!(!idents(&s).contains(&"Instant"));
        assert!(s.comment_on(1).is_some_and(|c| c.contains("Instant")));
        assert!(s.code_lines.contains(&1) && s.code_lines.contains(&2));
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let src = "let a = r#\"quote \" inside HashMap\"#; let b = r\"x\"; let c = br##\"y\"##;";
        let s = scan(src);
        assert!(!idents(&s).contains(&"HashMap"));
        assert_eq!(s.tokens.iter().filter(|t| t.kind == TokenKind::Str).count(), 3);
    }

    #[test]
    fn raw_identifier_is_not_a_string() {
        let s = scan("let r#type = 1;");
        assert!(idents(&s).contains(&"r"));
        assert!(idents(&s).contains(&"type"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\nlet nl = '\\n'; let q = b'\"';";
        let s = scan(src);
        let lifetimes: Vec<_> =
            s.tokens.iter().filter(|t| t.kind == TokenKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(s.tokens.iter().filter(|t| t.kind == TokenKind::Char).count(), 3);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* Mutex */ still comment */ let x = 1;";
        let s = scan(src);
        assert!(!idents(&s).contains(&"Mutex"));
        assert!(idents(&s).contains(&"x"));
    }

    #[test]
    fn multiline_string_tracks_lines() {
        let src = "let a = \"line one\nline two\";\nlet b = 2;";
        let s = scan(src);
        let b = s.tokens.iter().find(|t| t.text == "b").expect("b token");
        assert_eq!(b.line, 3);
    }

    #[test]
    fn numeric_suffixes_and_exponents_stay_single_tokens() {
        let s = scan("let a = 1.5e-3f32; let b = 0..10; let c = 0xFFu64;");
        let nums: Vec<&str> = s
            .tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5e-3f32", "0", "10", "0xFFu64"]);
    }

    #[test]
    fn cfg_test_mod_is_masked() {
        let src = "fn live() { a(); }\n#[cfg(test)]\nmod tests {\n  fn t() { b(); }\n}\nfn live2() { c(); }";
        let s = scan(src);
        let masked: Vec<&str> = s
            .tokens
            .iter()
            .zip(&s.in_test)
            .filter(|(_, &m)| m)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(masked.contains(&"b"));
        assert!(!masked.contains(&"a"));
        assert!(!masked.contains(&"c"));
    }

    #[test]
    fn cfg_all_test_and_stacked_attributes_are_masked() {
        let src = "#[cfg(all(test, unix))]\n#[allow(dead_code)]\nfn helper() { x(); }\nfn live() { y(); }";
        let s = scan(src);
        let masked: Vec<&str> = s
            .tokens
            .iter()
            .zip(&s.in_test)
            .filter(|(_, &m)| m)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(masked.contains(&"x"));
        assert!(!masked.contains(&"y"));
    }

    #[test]
    fn cfg_not_test_feature_is_not_masked() {
        let src = "#[cfg(feature = \"extra\")]\nfn gated() { x(); }";
        let s = scan(src);
        assert!(s.in_test.iter().all(|&m| !m));
    }
}
