//! The six determinism/concurrency rules, run over a [`Scan`].
//!
//! Scopes are path prefixes relative to the source root (`rust/src`):
//!
//! * **deterministic** (`engine/`, `knn/`, `ld/`, `hd/`, `metrics/`,
//!   `obs/`, `persist/`, `util/rng.rs`, `util/simd.rs`) — code whose
//!   outputs must be a pure function of (seed, iteration, input),
//!   bitwise-invariant to thread count (for `obs/`: a pure function of
//!   the samples fed in, with all timing through
//!   `util::timer::PhaseClock`; for `persist/`: snapshot bytes a pure
//!   function of session state, so restore equals replay);
//! * **sharded** (the same prefixes minus `util/rng.rs`, plus
//!   `util/simd.rs`) — code whose reductions run per-shard and must
//!   combine in a fixed order. The SIMD lane module lives here because
//!   its horizontal folds are exactly the reductions rule 6 exists to
//!   police: they stay legal only while spelled as the fixed-order
//!   pairwise tree in `F32x8::hsum`, never as `.sum()`/`.fold()`;
//! * **server** (`server/`) — request-handling code that must answer
//!   with HTTP statuses, never by panicking a worker.
//!
//! Every rule reports identifiers from the token stream only, so
//! strings, comments and fixture text can mention `Instant` or
//! `HashMap` freely. Rules 1, 2, 5 and 6 skip `#[cfg(test)]` items;
//! rules 3 and 4 apply to tests too (an unsound `unsafe` block or an
//! unranked lock is no better for living in a test).

use super::scanner::{Scan, Token, TokenKind};
use super::Finding;

/// Rule identifiers, as spelled in findings and `lint.toml` sections.
pub const WALL_CLOCK: &str = "wall_clock";
pub const HASH_COLLECTIONS: &str = "hash_collections";
pub const SAFETY_COMMENT: &str = "safety_comment";
pub const RAW_SYNC: &str = "raw_sync";
pub const SERVER_PANICS: &str = "server_panics";
pub const F32_REDUCTION: &str = "f32_reduction";

/// Every rule name, for config validation and reporting.
pub const RULE_NAMES: [&str; 6] =
    [WALL_CLOCK, HASH_COLLECTIONS, SAFETY_COMMENT, RAW_SYNC, SERVER_PANICS, F32_REDUCTION];

/// Module prefixes whose outputs must be thread-count-invariant.
/// `obs/` is here so observability can never smuggle a raw clock or a
/// hash map into timing-adjacent code: everything it measures goes
/// through `util::timer::PhaseClock` and ordered collections.
/// `persist/` is here because crash recovery leans on the same
/// guarantee from the other side: snapshot bytes must be a pure
/// function of session state, and WAL replay must re-drive the session
/// identically at any thread count — a stray clock or hash-ordered
/// iteration in the codecs would break restore-equals-replay.
const DETERMINISTIC_PREFIXES: [&str; 7] =
    ["engine/", "knn/", "ld/", "hd/", "metrics/", "obs/", "persist/"];

fn is_deterministic(rel: &str) -> bool {
    rel == "util/rng.rs"
        || rel == "util/simd.rs"
        || DETERMINISTIC_PREFIXES.iter().any(|p| rel.starts_with(p))
}

fn is_sharded(rel: &str) -> bool {
    rel == "util/simd.rs" || DETERMINISTIC_PREFIXES.iter().any(|p| rel.starts_with(p))
}

fn is_server(rel: &str) -> bool {
    rel.starts_with("server/")
}

/// Run every rule over one scanned file. `rel` is the path relative to
/// the source root, `/`-separated.
pub fn check(rel: &str, scan: &Scan) -> Vec<Finding> {
    let mut out = Vec::new();
    if is_deterministic(rel) {
        wall_clock(rel, scan, &mut out);
        hash_collections(rel, scan, &mut out);
    }
    safety_comment(rel, scan, &mut out);
    if rel != "runtime/sync.rs" {
        raw_sync(rel, scan, &mut out);
    }
    if is_server(rel) {
        server_panics(rel, scan, &mut out);
    }
    if is_sharded(rel) {
        f32_reduction(rel, scan, &mut out);
    }
    out
}

fn push(out: &mut Vec<Finding>, rel: &str, line: u32, rule: &'static str, message: String) {
    out.push(Finding { path: rel.to_string(), line, rule, message });
}

fn is_word(tokens: &[Token], i: usize, word: &str) -> bool {
    tokens.get(i).is_some_and(|t| t.kind == TokenKind::Ident && t.text == word)
}

fn is_punct(tokens: &[Token], i: usize, c: char) -> bool {
    tokens
        .get(i)
        .is_some_and(|t| t.kind == TokenKind::Punct && t.text.len() == 1 && t.text.starts_with(c))
}

/// Rule 1: no wall-clock reads in deterministic modules.
fn wall_clock(rel: &str, scan: &Scan, out: &mut Vec<Finding>) {
    for (i, t) in scan.tokens.iter().enumerate() {
        if scan.in_test[i] || t.kind != TokenKind::Ident {
            continue;
        }
        if t.text == "Instant" || t.text == "SystemTime" {
            push(
                out,
                rel,
                t.line,
                WALL_CLOCK,
                format!(
                    "wall-clock `{}` in a deterministic module; route timing through the \
                     `util::timer::PhaseClock` shim so engine outputs stay a pure function \
                     of (seed, iteration)",
                    t.text
                ),
            );
        }
    }
}

/// Rule 2: no `HashMap`/`HashSet` in deterministic modules — their
/// iteration order is randomized per process. Membership-only uses can
/// be waived in `lint.toml` with a justification.
fn hash_collections(rel: &str, scan: &Scan, out: &mut Vec<Finding>) {
    for (i, t) in scan.tokens.iter().enumerate() {
        if scan.in_test[i] || t.kind != TokenKind::Ident {
            continue;
        }
        if t.text == "HashMap" || t.text == "HashSet" {
            push(
                out,
                rel,
                t.line,
                HASH_COLLECTIONS,
                format!(
                    "`{}` in a deterministic module risks iteration-order nondeterminism; \
                     use `BTreeMap`/`BTreeSet`/`Vec`, or waive a membership-only use in \
                     lint.toml",
                    t.text
                ),
            );
        }
    }
}

/// Rule 3: every `unsafe` must carry a `// SAFETY:` justification on
/// the same line or in the contiguous comment block directly above.
fn safety_comment(rel: &str, scan: &Scan, out: &mut Vec<Finding>) {
    for t in &scan.tokens {
        if t.kind == TokenKind::Ident && t.text == "unsafe" && !has_safety_comment(scan, t.line) {
            push(
                out,
                rel,
                t.line,
                SAFETY_COMMENT,
                "`unsafe` without a `// SAFETY:` justification on the preceding line"
                    .to_string(),
            );
        }
    }
}

fn has_safety_comment(scan: &Scan, line: u32) -> bool {
    if scan.comment_on(line).is_some_and(|c| c.contains("SAFETY:")) {
        return true;
    }
    // Walk the contiguous comment block directly above; code or blank
    // lines end it (a code line's trailing comment still counts).
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        if scan.comment_on(l).is_some_and(|c| c.contains("SAFETY:")) {
            return true;
        }
        let comment_only =
            scan.comment_lines.contains_key(&l) && !scan.code_lines.contains(&l);
        if !comment_only {
            return false;
        }
        l -= 1;
    }
    false
}

/// Rule 4: no raw `std::sync` locks outside `runtime/sync.rs` — the
/// wrappers there rank locks, detect order cycles and recover poison.
fn raw_sync(rel: &str, scan: &Scan, out: &mut Vec<Finding>) {
    for t in &scan.tokens {
        if t.kind != TokenKind::Ident {
            continue;
        }
        if t.text == "Mutex" || t.text == "Condvar" || t.text == "RwLock" {
            push(
                out,
                rel,
                t.line,
                RAW_SYNC,
                format!(
                    "raw `std::sync::{}`; use the checked wrappers in `runtime::sync` \
                     (`DebugMutex`/`DebugCondvar`) so lock-order checking and poison \
                     recovery stay centralized",
                    t.text
                ),
            );
        }
    }
}

/// Rule 5: no `.unwrap()` / `.expect("...")` on server request paths —
/// failures must map to HTTP statuses, not worker panics. `.expect(`
/// counts only when its argument is a string literal, which excludes
/// same-named parser methods taking byte arguments.
fn server_panics(rel: &str, scan: &Scan, out: &mut Vec<Finding>) {
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        if scan.in_test[i] || !is_punct(toks, i, '.') {
            continue;
        }
        if is_word(toks, i + 1, "unwrap") && is_punct(toks, i + 2, '(') && is_punct(toks, i + 3, ')')
        {
            push(
                out,
                rel,
                toks[i + 1].line,
                SERVER_PANICS,
                "`.unwrap()` on a server request path; map the failure to a `ServiceError` \
                 (HTTP 4xx/5xx) instead of panicking the worker"
                    .to_string(),
            );
        } else if is_word(toks, i + 1, "expect")
            && is_punct(toks, i + 2, '(')
            && toks.get(i + 3).is_some_and(|t| t.kind == TokenKind::Str)
        {
            push(
                out,
                rel,
                toks[i + 1].line,
                SERVER_PANICS,
                "`.expect(\"...\")` on a server request path; map the failure to a \
                 `ServiceError` (HTTP 4xx/5xx) instead of panicking the worker"
                    .to_string(),
            );
        }
    }
}

/// Rule 6: no f32 `.sum()` / unordered `.fold()` reductions in sharded
/// modules — float addition is non-associative, so an unordered
/// combine varies with shard count. Folds whose combiner is `min`/
/// `max` (associative and commutative) are exempt.
fn f32_reduction(rel: &str, scan: &Scan, out: &mut Vec<Finding>) {
    let toks = &scan.tokens;
    for i in 0..toks.len() {
        if scan.in_test[i] || !is_punct(toks, i, '.') {
            continue;
        }
        if is_word(toks, i + 1, "sum") {
            if f32_near_call(toks, i) && !statement_has_minmax(toks, i) {
                push(out, rel, toks[i + 1].line, F32_REDUCTION, f32_message("sum"));
            }
        } else if is_word(toks, i + 1, "fold") && is_punct(toks, i + 2, '(') {
            let close = match_paren(toks, i + 2);
            let args = &toks[(i + 3).min(close)..close.min(toks.len())];
            let args_f32 = args.iter().any(is_f32_token);
            let minmax = args
                .iter()
                .any(|t| t.kind == TokenKind::Ident && (t.text == "min" || t.text == "max"));
            if (args_f32 || f32_near_call(toks, i)) && !minmax {
                push(out, rel, toks[i + 1].line, F32_REDUCTION, f32_message("fold"));
            }
        }
    }
}

fn f32_message(what: &str) -> String {
    format!(
        "f32 `.{what}()` reduction in a sharded module; combine per-shard f64 subtotals \
         in a fixed order instead (see docs/determinism.md) or waive in lint.toml"
    )
}

fn is_f32_token(t: &Token) -> bool {
    (t.kind == TokenKind::Ident && t.text == "f32")
        || (t.kind == TokenKind::Num && t.text.ends_with("f32"))
}

/// Is this reduction f32-typed as far as tokens can tell? Checks a
/// turbofish (`.sum::<f32>()`) ahead of the call and the statement
/// text behind it (`let s: f32 = ...`), bounded to one statement.
fn f32_near_call(toks: &[Token], dot: usize) -> bool {
    // Forward: between the method name and its `(` (turbofish).
    let mut j = dot + 2;
    while j < toks.len() && j < dot + 12 && !is_punct(toks, j, '(') {
        if is_f32_token(&toks[j]) {
            return true;
        }
        j += 1;
    }
    // Backward to the statement start.
    let mut j = dot;
    let mut budget = 256usize;
    while j > 0 && budget > 0 {
        j -= 1;
        budget -= 1;
        if is_punct(toks, j, ';') || is_punct(toks, j, '{') || is_punct(toks, j, '}') {
            break;
        }
        if is_f32_token(&toks[j]) {
            return true;
        }
    }
    false
}

/// Does the statement around `dot` mention `min`/`max`? Covers
/// `fold(f32::INFINITY, f32::min)` spelled via `.sum`-adjacent
/// helpers; kept narrow on purpose.
fn statement_has_minmax(toks: &[Token], dot: usize) -> bool {
    let mut j = dot;
    let mut budget = 64usize;
    while j > 0 && budget > 0 {
        j -= 1;
        budget -= 1;
        if is_punct(toks, j, ';') || is_punct(toks, j, '{') || is_punct(toks, j, '}') {
            return false;
        }
        if toks[j].kind == TokenKind::Ident && (toks[j].text == "min" || toks[j].text == "max") {
            return true;
        }
    }
    false
}

/// Index of the `)` matching the `(` at `open`, or `toks.len()`.
fn match_paren(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        if is_punct(toks, i, '(') {
            depth += 1;
        } else if is_punct(toks, i, ')') {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len()
}
