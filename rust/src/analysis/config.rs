//! `lint.toml`: per-rule allowlists with mandatory justifications.
//!
//! The format rides on [`crate::config::toml_lite`] — one section per
//! rule, one key per waived file, and the value is the human reason
//! the waiver exists (empty justifications are rejected, so every
//! waiver is documented at the point it is granted):
//!
//! ```toml
//! [allow.hash_collections]
//! util/rng.rs = "membership-only HashSet; never iterated"
//! ```
//!
//! Paths are relative to the scanned source root (`rust/src`), with
//! `/` separators. Unknown rule names are a hard error — a typo must
//! not silently waive nothing.

use crate::config::toml_lite;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use super::rules::RULE_NAMES;

/// Parsed allowlists: `(rule, path) → justification`.
#[derive(Clone, Debug, Default)]
pub struct LintConfig {
    allow: BTreeMap<(String, String), String>,
}

impl LintConfig {
    /// A config that waives nothing.
    pub fn empty() -> LintConfig {
        LintConfig::default()
    }

    /// Load and validate a `lint.toml` file.
    pub fn load(path: &Path) -> Result<LintConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read lint config {path:?}"))?;
        LintConfig::from_text(&text).with_context(|| format!("parse lint config {path:?}"))
    }

    /// Parse config text. Every key must be `allow.<rule>.<path>` with
    /// a known rule and a non-empty justification string.
    pub fn from_text(text: &str) -> Result<LintConfig> {
        let map = toml_lite::parse(text)?;
        let mut allow = BTreeMap::new();
        for (key, value) in &map {
            let Some(rest) = key.strip_prefix("allow.") else {
                bail!("unknown lint.toml key {key:?} (expected [allow.<rule>] sections)");
            };
            // Rule names contain no '.', so the first dot separates the
            // rule from the path (paths may contain dots: `rng.rs`).
            let Some((rule, path)) = rest.split_once('.') else {
                bail!("malformed lint.toml key {key:?} (expected allow.<rule>.<path>)");
            };
            if !RULE_NAMES.contains(&rule) {
                bail!("unknown lint rule {rule:?} in lint.toml (known: {RULE_NAMES:?})");
            }
            let why = match value {
                toml_lite::Value::Str(s) => s.trim().to_string(),
                other => bail!("waiver {key:?} must be a string justification, got {other:?}"),
            };
            if why.is_empty() {
                bail!("waiver {key:?} has an empty justification; say why it is safe");
            }
            allow.insert((rule.to_string(), path.trim().to_string()), why);
        }
        Ok(LintConfig { allow })
    }

    /// The justification waiving `rule` for `path`, if one exists.
    pub fn waiver(&self, rule: &str, path: &str) -> Option<&str> {
        self.allow.get(&(rule.to_string(), path.to_string())).map(String::as_str)
    }

    /// Number of waiver entries.
    pub fn len(&self) -> usize {
        self.allow.len()
    }

    pub fn is_empty(&self) -> bool {
        self.allow.is_empty()
    }

    /// All waivers as `(rule, path, justification)`, sorted.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &str)> {
        self.allow.iter().map(|((r, p), w)| (r.as_str(), p.as_str(), w.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_waivers_with_justifications() {
        let cfg = LintConfig::from_text(
            "[allow.hash_collections]\nutil/rng.rs = \"membership-only; never iterated\"\n",
        )
        .unwrap();
        assert_eq!(cfg.len(), 1);
        assert!(cfg.waiver("hash_collections", "util/rng.rs").is_some());
        assert!(cfg.waiver("hash_collections", "util/other.rs").is_none());
        assert!(cfg.waiver("wall_clock", "util/rng.rs").is_none());
    }

    #[test]
    fn unknown_rule_is_rejected() {
        let err = LintConfig::from_text("[allow.no_such_rule]\na.rs = \"x\"\n").unwrap_err();
        assert!(format!("{err:?}").contains("no_such_rule"));
    }

    #[test]
    fn empty_justification_is_rejected() {
        assert!(LintConfig::from_text("[allow.wall_clock]\na.rs = \"\"\n").is_err());
        assert!(LintConfig::from_text("[allow.wall_clock]\na.rs = \"  \"\n").is_err());
    }

    #[test]
    fn non_allow_sections_are_rejected() {
        assert!(LintConfig::from_text("[general]\nstrict = true\n").is_err());
    }

    #[test]
    fn empty_text_is_empty_config() {
        let cfg = LintConfig::from_text("").unwrap();
        assert!(cfg.is_empty());
    }
}
