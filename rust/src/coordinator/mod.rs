//! The coordinator: tiles engine work into fixed-shape batches and
//! dispatches them to the PJRT executables ([`PjrtBackend`]), plus the
//! high-level run driver shared by the CLI and the examples.

pub mod pjrt_backend;
pub mod driver;

pub use pjrt_backend::PjrtBackend;
