//! High-level run driver shared by the CLI, the examples and the bench
//! harnesses: dataset registry, backend factory, and an end-to-end
//! "embed + report" runner.

use crate::config::{Backend, EmbedConfig};
use crate::data::datasets::{self, Dataset};
use crate::data::Matrix;
use crate::engine::ComputeBackend;
use crate::ld::{NativeBackend, ParallelBackend, SimdBackend};
use crate::linalg::Pca;
use crate::session::Session;
use crate::util::Stopwatch;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory: `<crate root>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Instantiate a dataset by name (the registry the CLI / benches use).
///
/// Names: `scurve`, `scurve_unbalanced`, `blobs`, `blobs_overlap`,
/// `blobs_disjoint`, `coil`, `mnist`, `rat_brain`, `tabula`,
/// `deep_features`, `nested`.
pub fn dataset_by_name(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    Ok(match name {
        "scurve" => datasets::scurve(n, 0.02, false, seed),
        "scurve_unbalanced" => datasets::scurve(n, 0.02, true, seed),
        "blobs" => datasets::blobs(n, 32, 10, 1.0, 20.0, seed),
        "blobs_overlap" => datasets::blobs_overlapping(n, 32, seed),
        "blobs_disjoint" => {
            let per = 30;
            datasets::blobs_disjointed((n / per).max(2), per, 32, seed)
        }
        "coil" => datasets::coil_like(20, (n / 20).max(8), 48, seed),
        "mnist" => datasets::mnist_like(n, 64, seed),
        "rat_brain" => datasets::rat_brain_like(n, 50, seed),
        "tabula" => datasets::tabula_like(n, 50, seed),
        "deep_features" => datasets::deep_features(n, 100, 256, seed),
        "nested" => datasets::nested_blobs(n, 16, 4, 3, seed),
        other => bail!(
            "unknown dataset {other:?} (scurve|scurve_unbalanced|blobs|blobs_overlap|\
             blobs_disjoint|coil|mnist|rat_brain|tabula|deep_features|nested)"
        ),
    })
}

/// Build the configured compute backend. For PJRT the executables the
/// run needs are compiled up front (`warmup`). On the native path the
/// `threads` knob selects between the sequential reference backend and
/// the sharded [`ParallelBackend`] (bitwise-identical results, so the
/// choice never changes an embedding — only its wall-clock). The SIMD
/// backend composes the lane-vectorized kernels with the same sharding
/// at any `threads` setting (bitwise thread-count-invariant, close to
/// native within lane-fold tolerance).
pub fn make_backend(
    cfg: &EmbedConfig,
    data_dim: usize,
    artifact_dir: &Path,
) -> Result<Box<dyn ComputeBackend>> {
    match cfg.backend {
        Backend::Native => {
            let threads = cfg.resolved_threads();
            if threads > 1 {
                Ok(Box::new(ParallelBackend::new(threads)))
            } else {
                Ok(Box::new(NativeBackend::new()))
            }
        }
        Backend::Simd => Ok(Box::new(SimdBackend::new(cfg.resolved_threads()))),
        Backend::Pjrt => {
            let mut b = super::PjrtBackend::new(artifact_dir)
                .context("PJRT backend init (run `make artifacts`?)")?;
            b.warmup(cfg.k_hd, cfg.k_ld, cfg.n_neg, cfg.ld_dim, data_dim)?;
            Ok(Box::new(b))
        }
    }
}

/// Reduce wide data with PCA first (the paper's recommended
/// preprocessing, §3: "reduce the HD dimensionality of the data linearly
/// to a manageable number of dimensions").
pub fn maybe_pca_reduce(x: Matrix, max_dim: usize, seed: u64) -> Matrix {
    if x.d() > max_dim {
        Pca::fit_transform(&x, max_dim, seed)
    } else {
        x
    }
}

/// Result of an end-to-end run. The finished [`Session`] is handed
/// back so callers can read the embedding, stats, or keep steering it.
pub struct RunReport {
    pub session: Session,
    pub seconds: f64,
    pub iters_per_sec: f64,
}

/// End-to-end convenience: a thin wrapper over the session facade —
/// build a [`Session`], run its configured `n_iters`, time it.
///
/// `pca_max_dim` routes through [`crate::session::SessionBuilder::pca_max_dim`],
/// so the returned session retains the fitted basis and keeps accepting
/// original-dimension rows for dynamic commands (pre-reducing `x` by
/// hand before this call would silently lose that).
pub fn run_embedding(
    x: Matrix,
    cfg: &EmbedConfig,
    artifact_dir: &Path,
    pca_max_dim: Option<usize>,
) -> Result<RunReport> {
    let mut builder = Session::builder().dataset(x).config(cfg.clone()).artifact_dir(artifact_dir);
    if let Some(max_dim) = pca_max_dim {
        builder = builder.pca_max_dim(max_dim);
    }
    let mut session = builder.build()?;
    let sw = Stopwatch::new();
    session.run_configured()?;
    let seconds = sw.elapsed_s();
    Ok(RunReport { session, seconds, iters_per_sec: cfg.n_iters as f64 / seconds.max(1e-9) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_registry_resolves_all_names() {
        for name in [
            "scurve",
            "scurve_unbalanced",
            "blobs",
            "blobs_overlap",
            "blobs_disjoint",
            "coil",
            "mnist",
            "rat_brain",
            "tabula",
            "deep_features",
            "nested",
        ] {
            let ds = dataset_by_name(name, 300, 1).unwrap();
            assert!(ds.n() >= 200, "{name} produced too few points: {}", ds.n());
            assert_eq!(ds.labels.len(), ds.n());
        }
        assert!(dataset_by_name("nope", 10, 1).is_err());
    }

    #[test]
    fn pca_reduction_only_when_wide() {
        let ds = dataset_by_name("mnist", 200, 2).unwrap();
        let reduced = maybe_pca_reduce(ds.x.clone(), 16, 0);
        assert_eq!(reduced.d(), 16);
        let narrow = dataset_by_name("scurve", 100, 2).unwrap();
        let kept = maybe_pca_reduce(narrow.x.clone(), 16, 0);
        assert_eq!(kept.d(), 3);
    }

    #[test]
    fn make_backend_honours_threads_knob() {
        // Backend pinned explicitly: the default honours the ambient
        // FUNCSNE_BACKEND variable, which this test must not depend on.
        let dir = default_artifact_dir();
        let base = EmbedConfig { backend: Backend::Native, ..EmbedConfig::default() };
        let cfg = EmbedConfig { threads: 1, ..base.clone() };
        assert_eq!(make_backend(&cfg, 8, &dir).unwrap().name(), "native");
        let cfg = EmbedConfig { threads: 4, ..base };
        assert_eq!(make_backend(&cfg, 8, &dir).unwrap().name(), "parallel");
    }

    #[test]
    fn make_backend_selects_simd_at_any_thread_count() {
        let dir = default_artifact_dir();
        for threads in [1usize, 4] {
            let cfg = EmbedConfig { backend: Backend::Simd, threads, ..EmbedConfig::default() };
            assert_eq!(make_backend(&cfg, 8, &dir).unwrap().name(), "simd");
        }
    }

    #[test]
    fn run_embedding_native_end_to_end() {
        let ds = dataset_by_name("blobs", 200, 3).unwrap();
        let cfg = EmbedConfig {
            n_iters: 40,
            k_hd: 10,
            k_ld: 6,
            perplexity: 6.0,
            jumpstart_iters: 5,
            ..EmbedConfig::default()
        };
        let report = run_embedding(ds.x, &cfg, &default_artifact_dir(), None).unwrap();
        assert_eq!(report.session.iterations(), 40);
        assert!(report.iters_per_sec > 0.0);
    }

    #[test]
    fn run_embedding_with_pca_retains_basis() {
        let ds = dataset_by_name("mnist", 200, 4).unwrap();
        let cfg = EmbedConfig {
            n_iters: 10,
            k_hd: 10,
            k_ld: 6,
            perplexity: 6.0,
            jumpstart_iters: 0,
            ..EmbedConfig::default()
        };
        let report = run_embedding(ds.x, &cfg, &default_artifact_dir(), Some(16)).unwrap();
        assert_eq!(report.session.engine().x.d(), 16);
        let pca = report.session.pca().expect("basis must be retained for dynamic rows");
        assert_eq!((pca.input_dim(), pca.out_dim()), (64, 16));
    }
}
