//! The PJRT compute backend: gathers engine state into fixed-shape
//! tiles, pads + masks, and dispatches the AOT-compiled XLA executables.
//!
//! This is the three-layer hot path: all per-slot kernel math (Eq. 4/5)
//! runs inside the Pallas-lowered HLO; Rust does gathers, padding and
//! scatter-accumulation only. Semantics are bit-for-bit the slot rules
//! of [`crate::ld::NativeBackend`] (the parity integration test in
//! `rust/tests/parity.rs` enforces agreement).

use crate::data::Matrix;
use crate::engine::backend::{ComputeBackend, NegSamples, NegStats};
use crate::hd::Affinities;
use crate::knn::iterative::IterativeKnn;
use crate::runtime::artifacts::{ArtifactKind, ArtifactSpec};
use crate::runtime::pjrt::PjrtRuntime;
use anyhow::{Context, Result};
use std::path::Path;

/// Which slot group a forces tile call represents.
#[derive(Clone, Copy, PartialEq)]
enum Group {
    Hd,
    Ld,
    Neg,
}

/// PJRT-backed [`ComputeBackend`].
pub struct PjrtBackend {
    rt: PjrtRuntime,
    // reusable tile buffers
    yi: Vec<f32>,
    yj: Vec<f32>,
    p: Vec<f32>,
    mask: Vec<f32>,
    attr_out: Vec<f32>,
    rep_out: Vec<f32>,
    wsum_out: Vec<f32>,
    sq_a: Vec<f32>,
    sq_b: Vec<f32>,
    sq_out: Vec<f32>,
}

impl PjrtBackend {
    /// Open the artifact directory and create the PJRT client.
    pub fn new(artifact_dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            rt: PjrtRuntime::new(artifact_dir)?,
            yi: Vec::new(),
            yj: Vec::new(),
            p: Vec::new(),
            mask: Vec::new(),
            attr_out: Vec::new(),
            rep_out: Vec::new(),
            wsum_out: Vec::new(),
            sq_a: Vec::new(),
            sq_b: Vec::new(),
            sq_out: Vec::new(),
        })
    }

    /// Pre-compile the executables an engine configuration needs.
    pub fn warmup(&mut self, k_hd: usize, k_ld: usize, n_neg: usize, d: usize, m: usize) -> Result<()> {
        self.rt.warmup(k_hd, k_ld, n_neg, d, m)
    }

    /// Per-artifact execution counts, sorted by artifact name so any
    /// serialization of them is byte-deterministic.
    pub fn exec_counts(&self) -> &std::collections::BTreeMap<String, u64> {
        &self.rt.exec_counts
    }

    /// One slot group over the whole point set, tiled at the artifact's
    /// B. Adds `scale`·rep into `rep_acc`, attraction into `attr_acc`
    /// (HD group only), and returns (Σ wsum over valid slots, number of
    /// valid slots) — the slot count feeds [`NegStats::covered`] for the
    /// near-field groups.
    #[allow(clippy::too_many_arguments)]
    fn forces_group(
        &mut self,
        spec: &ArtifactSpec,
        group: Group,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        scale: f32,
        attr_acc: &mut Matrix,
        rep_acc: &mut Matrix,
    ) -> Result<(f64, usize)> {
        let ArtifactKind::Forces { b, k, d } = spec.kind else {
            anyhow::bail!("not a forces artifact");
        };
        let n = y.n();
        debug_assert_eq!(y.d(), d);
        self.yi.resize(b * d, 0.0);
        self.yj.resize(b * k * d, 0.0);
        self.p.resize(b * k, 0.0);
        self.mask.resize(b * k, 0.0);
        self.attr_out.resize(b * d, 0.0);
        self.rep_out.resize(b * d, 0.0);
        self.wsum_out.resize(b, 0.0);
        let mut wsum_total = 0.0f64;
        let mut valid_slots = 0usize;
        let mut base = 0usize;
        while base < n {
            let rows = (n - base).min(b);
            // ---- gather -------------------------------------------------
            self.yi.iter_mut().for_each(|v| *v = 0.0);
            self.p.iter_mut().for_each(|v| *v = 0.0);
            self.mask.iter_mut().for_each(|v| *v = 0.0);
            // yj can stay stale where mask is 0.
            for r in 0..rows {
                let i = base + r;
                self.yi[r * d..(r + 1) * d].copy_from_slice(y.row(i));
                match group {
                    Group::Hd => {
                        for (s, (j, _)) in knn.hd.entries(i).enumerate() {
                            let off = (r * k + s) * d;
                            self.yj[off..off + d].copy_from_slice(y.row(j as usize));
                            self.p[r * k + s] = aff.p_slot(i, s);
                            self.mask[r * k + s] = 1.0;
                            valid_slots += 1;
                        }
                    }
                    Group::Ld => {
                        for (s, (j, _)) in knn.ld.entries(i).enumerate() {
                            if knn.hd.contains(i, j) {
                                continue; // Eq. 6 term-1 already covers it
                            }
                            let off = (r * k + s) * d;
                            self.yj[off..off + d].copy_from_slice(y.row(j as usize));
                            self.mask[r * k + s] = 1.0;
                            valid_slots += 1;
                        }
                    }
                    Group::Neg => {
                        for (s, &j) in neg.row(i).iter().enumerate() {
                            let off = (r * k + s) * d;
                            self.yj[off..off + d].copy_from_slice(y.row(j as usize));
                            self.mask[r * k + s] = 1.0;
                        }
                    }
                }
            }
            // ---- dispatch ----------------------------------------------
            // (borrow juggling: move buffers out, call, move back)
            let yi = std::mem::take(&mut self.yi);
            let yj = std::mem::take(&mut self.yj);
            let p = std::mem::take(&mut self.p);
            let mask = std::mem::take(&mut self.mask);
            let mut attr_out = std::mem::take(&mut self.attr_out);
            let mut rep_out = std::mem::take(&mut self.rep_out);
            let mut wsum_out = std::mem::take(&mut self.wsum_out);
            let res = self.rt.exec_forces(
                spec,
                alpha,
                &yi,
                &yj,
                &p,
                &mask,
                &mut attr_out,
                &mut rep_out,
                &mut wsum_out,
            );
            self.yi = yi;
            self.yj = yj;
            self.p = p;
            self.mask = mask;
            self.attr_out = attr_out;
            self.rep_out = rep_out;
            self.wsum_out = wsum_out;
            res?;
            // ---- scatter-accumulate -------------------------------------
            for r in 0..rows {
                let i = base + r;
                if group == Group::Hd {
                    let arow = &mut attr_acc.data_mut()[i * d..(i + 1) * d];
                    for c in 0..d {
                        arow[c] += self.attr_out[r * d + c];
                    }
                }
                let rrow = &mut rep_acc.data_mut()[i * d..(i + 1) * d];
                for c in 0..d {
                    rrow[c] += scale * self.rep_out[r * d + c];
                }
                wsum_total += self.wsum_out[r] as f64;
            }
            base += rows;
        }
        Ok((wsum_total, valid_slots))
    }
}

impl ComputeBackend for PjrtBackend {
    fn sqdist_batch(
        &mut self,
        x: &Matrix,
        owners: &[u32],
        cands: &[u32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        debug_assert_eq!(owners.len(), cands.len());
        let m_data = x.d();
        let spec = self
            .rt
            .manifest
            .find_sqdist(m_data)
            .cloned()
            .with_context(|| format!("no sqdist artifact covers M={m_data}"))?;
        let ArtifactKind::Sqdist { t, m } = spec.kind else { unreachable!() };
        out.clear();
        out.reserve(owners.len());
        self.sq_a.resize(t * m, 0.0);
        self.sq_b.resize(t * m, 0.0);
        self.sq_out.resize(t, 0.0);
        let mut base = 0usize;
        while base < owners.len() {
            let rows = (owners.len() - base).min(t);
            // §Perf: only the pad *columns* of used rows need zeroing —
            // unused tail rows produce outputs that are discarded, and a
            // full-tile memset (2×T·M f32 ≈ 1 MiB at M=32) cost ~20% of
            // the call.
            for r in 0..rows {
                let i = owners[base + r] as usize;
                let j = cands[base + r] as usize;
                self.sq_a[r * m..r * m + m_data].copy_from_slice(x.row(i));
                self.sq_b[r * m..r * m + m_data].copy_from_slice(x.row(j));
                if m_data < m {
                    self.sq_a[r * m + m_data..(r + 1) * m].iter_mut().for_each(|v| *v = 0.0);
                    self.sq_b[r * m + m_data..(r + 1) * m].iter_mut().for_each(|v| *v = 0.0);
                }
            }
            let a = std::mem::take(&mut self.sq_a);
            let b = std::mem::take(&mut self.sq_b);
            let mut o = std::mem::take(&mut self.sq_out);
            let res = self.rt.exec_sqdist(&spec, &a, &b, &mut o);
            self.sq_a = a;
            self.sq_b = b;
            self.sq_out = o;
            res?;
            out.extend_from_slice(&self.sq_out[..rows]);
            base += rows;
        }
        Ok(())
    }

    fn forces(
        &mut self,
        y: &Matrix,
        knn: &IterativeKnn,
        aff: &Affinities,
        neg: &NegSamples,
        alpha: f32,
        far_scale: f32,
        attr: &mut Matrix,
        rep: &mut Matrix,
    ) -> Result<NegStats> {
        let d = y.d();
        attr.data_mut().iter_mut().for_each(|v| *v = 0.0);
        rep.data_mut().iter_mut().for_each(|v| *v = 0.0);
        let hd_spec = self
            .rt
            .manifest
            .find_forces(knn.hd.k(), d)
            .cloned()
            .with_context(|| {
                format!(
                    "no forces artifact for K>={}, D={d} (dims available: {:?})",
                    knn.hd.k(),
                    self.rt.manifest.forces_dims()
                )
            })?;
        let (_, hd_slots) = self.forces_group(
            &hd_spec, Group::Hd, y, knn, aff, neg, alpha, 1.0, attr, rep,
        )?;
        let ld_spec = self
            .rt
            .manifest
            .find_forces(knn.ld.k(), d)
            .cloned()
            .context("no forces artifact for the LD group")?;
        // attr is untouched by non-HD groups (their p is all-zero and the
        // scatter phase only writes attr for Group::Hd).
        let (_, ld_slots) = self.forces_group(
            &ld_spec, Group::Ld, y, knn, aff, neg, alpha, 1.0, attr, rep,
        )?;
        let mut stats = NegStats { covered: hd_slots + ld_slots, ..NegStats::default() };
        if neg.m > 0 {
            let neg_spec = self
                .rt
                .manifest
                .find_forces(neg.m, d)
                .cloned()
                .context("no forces artifact for the negative-sample group")?;
            let (wsum, _) = self.forces_group(
                &neg_spec, Group::Neg, y, knn, aff, neg, alpha, far_scale, attr, rep,
            )?;
            stats.wsum = wsum;
            stats.count = y.n() * neg.m;
        }
        Ok(stats)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
