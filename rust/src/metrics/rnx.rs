//! R_NX(K) — the multi-scale neighbourhood-preservation criterion of
//! Lee, Peluffo-Ordóñez & Verleysen [23], the paper's main quantitative
//! metric (Figs 4, 6, 7).
//!
//! Q_NX(K) is the average (over points) fraction of each point's K HD
//! neighbours retrieved among its K LD neighbours. R_NX rescales it
//! against the random baseline K/(N−1):
//!
//! ```text
//! R_NX(K) = ((N−1)·Q_NX(K) − K) / (N−1−K)
//! ```
//!
//! The scalar summary is the **log-weighted AUC**:
//! `AUC = Σ_K R_NX(K)/K / Σ_K 1/K`, emphasising local scales.

use crate::data::Matrix;
use crate::knn::brute::brute_knn;
use crate::knn::NeighborTable;

/// An R_NX curve with its per-point spread (the Fig. 7 bands).
#[derive(Clone, Debug)]
pub struct RnxCurve {
    /// Scales K = 1..=k_max.
    pub ks: Vec<usize>,
    /// R_NX at each scale.
    pub rnx: Vec<f64>,
    /// Std-dev across points of the per-point R_NX at each scale.
    pub std: Vec<f64>,
    /// Log-weighted AUC.
    pub auc: f64,
}

/// Ranked neighbour lists (ascending distance), truncated at `k`.
fn ranked(x: &Matrix, k: usize) -> Vec<Vec<u32>> {
    let t = brute_knn(x, k);
    (0..x.n()).map(|i| t.sorted_neighbors(i)).collect()
}

/// R_NX curve comparing neighbourhoods of `hd` (reference) and `ld`
/// (embedding) up to scale `k_max`.
pub fn rnx_curve(hd: &Matrix, ld: &Matrix, k_max: usize) -> RnxCurve {
    let n = hd.n();
    assert_eq!(n, ld.n());
    assert!(n >= 3, "R_NX needs at least 3 points");
    let k_max = k_max.min(n - 2);
    let hd_rank = ranked(hd, k_max);
    let ld_rank = ranked(ld, k_max);
    rnx_from_ranked(&hd_rank, &ld_rank, n, k_max)
}

/// R_NX where the reference neighbourhoods come from a precomputed exact
/// table (avoids recomputing ground truth in sweeps).
pub fn rnx_curve_vs_table(truth: &NeighborTable, approx: &NeighborTable, k_max: usize) -> RnxCurve {
    let n = truth.n();
    let k_max = k_max.min(truth.k()).min(approx.k()).min(n.saturating_sub(2));
    let t_rank: Vec<Vec<u32>> = (0..n).map(|i| truth.sorted_neighbors(i)).collect();
    let a_rank: Vec<Vec<u32>> = (0..n).map(|i| approx.sorted_neighbors(i)).collect();
    rnx_from_ranked(&t_rank, &a_rank, n, k_max)
}

fn rnx_from_ranked(hd_rank: &[Vec<u32>], ld_rank: &[Vec<u32>], n: usize, k_max: usize) -> RnxCurve {
    // Per point, walk both ranked lists with an incremental intersection
    // count — O(N·K) with a membership bitmap reused across points.
    let mut ks = Vec::with_capacity(k_max);
    let mut rnx = vec![0.0f64; k_max];
    let mut std = vec![0.0f64; k_max];
    let mut qnx_sum = vec![0.0f64; k_max];
    let mut qnx_sq = vec![0.0f64; k_max];
    let mut in_hd = vec![u32::MAX; n]; // stamp: in_hd[j] == i means member
    for i in 0..n {
        let hr = &hd_rank[i];
        let lr = &ld_rank[i];
        let kk = k_max.min(hr.len()).min(lr.len());
        // Incremental: at scale K, intersection of first K of each list.
        // Use stamped membership of HD prefix and count LD hits ≤ K.
        let mut inter = 0usize;
        let mut ld_seen = vec![false; kk]; // ld_seen[t]: lr[t] already matched
        for kq in 0..kk {
            // Add hr[kq] to the HD prefix.
            in_hd[hr[kq] as usize] = i as u32;
            // Does any unmatched LD prefix element equal hr[kq]?
            // Check the new HD element against LD prefix (t <= kq):
            for (t, seen) in ld_seen.iter_mut().enumerate().take(kq + 1) {
                if !*seen && lr[t] == hr[kq] {
                    *seen = true;
                    inter += 1;
                    break;
                }
            }
            // And the newly-revealed LD element lr[kq] against HD prefix:
            if !ld_seen[kq] && in_hd[lr[kq] as usize] == i as u32 {
                // Guard against double count when lr[kq] == hr[kq] handled above.
                ld_seen[kq] = true;
                inter += 1;
            }
            let q = inter as f64 / (kq + 1) as f64;
            qnx_sum[kq] += q;
            qnx_sq[kq] += q * q;
        }
        // Pad short lists (shouldn't happen with brute tables).
        for kq in kk..k_max {
            qnx_sum[kq] += 0.0;
        }
    }
    for kq in 0..k_max {
        let k = kq + 1;
        ks.push(k);
        let q_mean = qnx_sum[kq] / n as f64;
        let q_var = (qnx_sq[kq] / n as f64 - q_mean * q_mean).max(0.0);
        let denom = (n - 1 - k) as f64;
        if denom <= 0.0 {
            rnx[kq] = 0.0;
            std[kq] = 0.0;
        } else {
            rnx[kq] = ((n - 1) as f64 * q_mean - k as f64) / denom;
            // Per-point R_NX std: linear transform of Q_NX std.
            std[kq] = (n - 1) as f64 * q_var.sqrt() / denom;
        }
    }
    let auc = log_weighted_auc(&ks, &rnx);
    RnxCurve { ks, rnx, std, auc }
}

/// Log-weighted AUC of an R_NX curve.
pub fn log_weighted_auc(ks: &[usize], rnx: &[f64]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (&k, &r) in ks.iter().zip(rnx) {
        let w = 1.0 / k as f64;
        num += r * w;
        den += w;
    }
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Convenience scalar: AUC of R_NX between HD data and an embedding.
pub fn rnx_auc(hd: &Matrix, ld: &Matrix, k_max: usize) -> f64 {
    rnx_curve(hd, ld, k_max).auc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::util::proptest as pt;
    use crate::util::Rng;

    #[test]
    fn identity_embedding_scores_one() {
        let ds = datasets::blobs(80, 5, 3, 0.5, 6.0, 1);
        let c = rnx_curve(&ds.x, &ds.x, 20);
        for (&k, &r) in c.ks.iter().zip(&c.rnx) {
            assert!(r > 0.999, "R_NX({k}) = {r} for identity");
        }
        assert!(c.auc > 0.999);
    }

    #[test]
    fn random_embedding_scores_near_zero() {
        let ds = datasets::blobs(150, 6, 3, 0.5, 8.0, 2);
        let mut rng = Rng::new(3);
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, 150, 2, 1.0), 150, 2).unwrap();
        let c = rnx_curve(&ds.x, &y, 40);
        assert!(c.auc.abs() < 0.15, "random AUC should be ~0, got {}", c.auc);
    }

    #[test]
    fn partial_preservation_in_between() {
        // Keep 3 of 6 coordinates: neighbourhoods partially survive.
        let ds = datasets::blobs(120, 6, 4, 1.0, 6.0, 4);
        let mut y = Matrix::zeros(120, 3);
        for i in 0..120 {
            y.row_mut(i).copy_from_slice(&ds.x.row(i)[..3]);
        }
        let auc = rnx_auc(&ds.x, &y, 30);
        assert!(auc > 0.1 && auc < 0.98, "partial AUC = {auc}");
    }

    #[test]
    fn rnx_in_valid_range() {
        pt::check("rnx-range", 10, |rng, _| {
            let n = rng.range_usize(10, 50);
            let x = Matrix::from_vec(pt::gauss_mat(rng, n, 4, 1.0), n, 4).unwrap();
            let y = Matrix::from_vec(pt::gauss_mat(rng, n, 2, 1.0), n, 2).unwrap();
            let c = rnx_curve(&x, &y, 12);
            for &r in &c.rnx {
                crate::prop_assert!(
                    (-1.1..=1.0001).contains(&r),
                    "R_NX out of range: {r}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn table_variant_matches_matrix_variant() {
        let ds = datasets::blobs(60, 5, 2, 0.6, 6.0, 5);
        let y = {
            let mut rng = Rng::new(6);
            Matrix::from_vec(pt::gauss_mat(&mut rng, 60, 2, 1.0), 60, 2).unwrap()
        };
        let k = 15;
        let c1 = rnx_curve(&ds.x, &y, k);
        let t_hd = crate::knn::brute::brute_knn(&ds.x, k);
        let t_ld = crate::knn::brute::brute_knn(&y, k);
        let c2 = rnx_curve_vs_table(&t_hd, &t_ld, k);
        for (a, b) in c1.rnx.iter().zip(&c2.rnx) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn auc_weights_local_scales() {
        let ks = vec![1, 2, 4, 8];
        // High at K=1 only vs high at K=8 only: the former wins.
        let local = log_weighted_auc(&ks, &[1.0, 0.0, 0.0, 0.0]);
        let global = log_weighted_auc(&ks, &[0.0, 0.0, 0.0, 1.0]);
        assert!(local > global);
    }
}
