//! Per-point quality measures used as the "colour maps" of Fig. 1:
//!
//! * row 1 — correlation, per point, between its distances to all other
//!   points in HD and in LD (global-structure preservation);
//! * row 2 — fraction of the first ⌈0.05·N⌉ HD neighbours preserved in
//!   the LD neighbourhood of the same size (local soundness).

use crate::data::matrix::dist;
use crate::data::Matrix;
use crate::knn::brute::brute_knn;
use crate::util::stats::pearson;

/// Per-point Pearson correlation between HD and LD distance profiles.
pub fn pointwise_distance_correlation(x: &Matrix, y: &Matrix) -> Vec<f64> {
    let n = x.n();
    assert_eq!(n, y.n());
    let mut out = Vec::with_capacity(n);
    let mut dh = vec![0.0f64; n - 1];
    let mut dl = vec![0.0f64; n - 1];
    for i in 0..n {
        let mut t = 0;
        for j in 0..n {
            if j == i {
                continue;
            }
            dh[t] = dist(x.row(i), x.row(j)) as f64;
            dl[t] = dist(y.row(i), y.row(j)) as f64;
            t += 1;
        }
        out.push(pearson(&dh, &dl));
    }
    out
}

/// Per-point preservation of the first K = ⌈frac·N⌉ neighbours
/// (intersection over K), the paper's second Fig. 1 row with frac=0.05.
pub fn pointwise_knn_preservation(x: &Matrix, y: &Matrix, frac: f64) -> Vec<f64> {
    let n = x.n();
    let k = ((frac * n as f64).ceil() as usize).clamp(1, n - 1);
    let tx = brute_knn(x, k);
    let ty = brute_knn(y, k);
    (0..n)
        .map(|i| {
            let hits = tx.neighbors(i).iter().filter(|&&j| ty.contains(i, j)).count();
            hits as f64 / k as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::util::proptest as pt;
    use crate::util::Rng;

    #[test]
    fn identity_gets_perfect_scores() {
        let ds = datasets::blobs(60, 4, 2, 0.5, 5.0, 1);
        let corr = pointwise_distance_correlation(&ds.x, &ds.x);
        assert!(corr.iter().all(|&c| c > 0.999));
        let pres = pointwise_knn_preservation(&ds.x, &ds.x, 0.05);
        assert!(pres.iter().all(|&p| p > 0.999));
    }

    #[test]
    fn random_embedding_scores_poorly() {
        let ds = datasets::blobs(100, 5, 3, 0.5, 8.0, 2);
        let mut rng = Rng::new(3);
        let y = crate::data::Matrix::from_vec(pt::gauss_mat(&mut rng, 100, 2, 1.0), 100, 2)
            .unwrap();
        let corr = pointwise_distance_correlation(&ds.x, &y);
        let mean_c = crate::util::stats::mean(&corr);
        assert!(mean_c.abs() < 0.3, "mean corr {mean_c}");
        let pres = pointwise_knn_preservation(&ds.x, &y, 0.05);
        let mean_p = crate::util::stats::mean(&pres);
        assert!(mean_p < 0.4, "mean preservation {mean_p}");
    }

    #[test]
    fn outputs_have_point_count_length() {
        let ds = datasets::blobs(40, 4, 2, 0.5, 5.0, 4);
        assert_eq!(pointwise_distance_correlation(&ds.x, &ds.x).len(), 40);
        assert_eq!(pointwise_knn_preservation(&ds.x, &ds.x, 0.05).len(), 40);
    }
}
