//! Online quality probe: cheap *sampled* structure-preservation metrics
//! computed **during** the run, so interactive hyperparameter changes
//! come with a live quality signal instead of a post-hoc O(N²) batch
//! evaluation (the running-quality methodology of the
//! attraction-repulsion-spectrum line of work, and the paper's own
//! Fig. 6/7 evaluation style).
//!
//! # Estimators
//!
//! A fixed, seeded **anchor subset** of `A` points (default 256) is
//! sampled once at construction. For each anchor the probe stores its
//! exact brute-force HD squared-distance row to *all* points — computed
//! once, O(A·N·d), then *patched* on dynamic mutation: O(A·d) per
//! insert, O(A) per remove, O(A·d) per move — except that moving a
//! point that is *itself* an anchor rescans its whole row, O(N·d) —
//! and each measurement computes, per anchor:
//!
//! * **KNN recall@k** — overlap between the anchor's exact HD k-NN and
//!   its exact *embedding* k-NN (both over the full point set);
//! * **trustworthiness / continuity** (Venna & Kaski) — rank penalties
//!   for intruders/missing points in the anchor's k-neighbourhood,
//!   normalised by the maximum achievable penalty per query
//!   (`k·(2n−3k−1)/2` for `k < n/2`, `(n−k)·(n−k−1)/2` otherwise — the
//!   two-case form keeps the score in [0, 1] at every dataset size),
//!   with the population sum replaced by the anchor sum;
//! * **iterative-KNN recall** — overlap between the anchor's exact HD
//!   k-NN and the engine's *estimated* [`NeighborTable`] row: the
//!   paper's central ANN-quality claim, measured at runtime against
//!   ground truth that is already paid for.
//!
//! # Bias
//!
//! All four numbers are unbiased Monte-Carlo estimates of their
//! full-population counterparts **at construction time**: anchors are a
//! uniform sample without replacement. Two sources of bias appear under
//! dynamic data: (1) points inserted later can never become anchors
//! (they still appear as *neighbours* of anchors, so they are not
//! invisible — but the query side of the estimate ignores them), and
//! (2) removing an anchored point shrinks the sample (anchor
//! attrition) rather than resampling, to keep the estimate seed-stable.
//! Both effects are second-order while insertions/removals are a small
//! fraction of N; recreate the session for a fresh sample otherwise.
//!
//! # Determinism
//!
//! Measurements are **bitwise-deterministic** for a fixed seed at any
//! thread count and any anchor sampling order: anchors are kept sorted
//! by index, per-anchor partial statistics are exact integers (hit
//! counts and rank penalties), and the final fold walks anchors in
//! index order — the same discipline as
//! [`crate::ld::ParallelBackend`]'s per-point f64 subtotals. Work is
//! sharded across a [`WorkerPool`] by contiguous anchor ranges, each
//! shard writing a disjoint slice. Note the probe still runs
//! *synchronously inside* [`crate::engine::FuncSne::step`] on probe
//! iterations — sharding shortens that stall and `probe_every`
//! amortises it (1-in-`probe_every` steps pay it), but it is not
//! asynchronous; none of this ever changes a bit of the output.

use crate::data::matrix::{sqdist, Matrix};
use crate::knn::NeighborTable;
use crate::runtime::pool::{shard_ranges, WorkerPool};
use crate::util::Rng;

/// Default `k` for recall@k / trustworthiness / continuity.
pub const DEFAULT_K: usize = 10;

/// Probe construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct ProbeConfig {
    /// Anchor-subset size (clamped to N).
    pub anchors: usize,
    /// Neighbourhood size for all four metrics.
    pub k: usize,
    /// Seed for the anchor sample (derived from the engine seed).
    pub seed: u64,
    /// Worker threads for the sharded measurement (resolved; ≥ 1).
    pub threads: usize,
}

impl Default for ProbeConfig {
    fn default() -> Self {
        ProbeConfig { anchors: 256, k: DEFAULT_K, seed: 42, threads: 1 }
    }
}

/// One quality measurement (all metrics in [0, 1]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QualityReport {
    /// Iteration the measurement was taken at.
    pub iter: usize,
    /// Anchors that contributed (≤ configured after attrition).
    pub anchors: usize,
    /// Effective neighbourhood size used.
    pub k: usize,
    /// Sampled embedding KNN recall@k vs exact HD neighbours.
    pub knn_recall: f64,
    /// Sampled trustworthiness (LD-neighbourhood intruder penalty).
    pub trustworthiness: f64,
    /// Sampled continuity (HD-neighbourhood miss penalty).
    pub continuity: f64,
    /// Iterative-KNN (estimated HD table) recall vs anchor ground truth.
    pub knn_recall_hd: f64,
}

/// Exact integer partial statistics for one anchor. Integers make the
/// cross-anchor reduction trivially order- and sharding-invariant.
#[derive(Clone, Copy, Debug, Default)]
struct AnchorStats {
    hits: u64,
    hits_hd: u64,
    trust_pen: u64,
    cont_pen: u64,
}

/// The probe: seeded anchors + patched brute-force HD ground truth.
pub struct QualityProbe {
    cfg: ProbeConfig,
    /// Anchor point indices, **sorted ascending** (the fold order).
    anchors: Vec<u32>,
    /// Per anchor: squared HD distance to every point (len = N),
    /// parallel to `anchors`. Patched on insert/remove/move.
    rows: Vec<Vec<f32>>,
    pool: WorkerPool,
}

/// `(d, idx)` strict total order (index breaks distance ties), shared
/// by selection and ranking so the two can never disagree.
#[inline(always)]
fn closer(d1: f32, j1: u32, d2: f32, j2: u32) -> bool {
    d1 < d2 || (d1 == d2 && j1 < j2)
}

/// The `k` nearest entries of `row` (skipping `skip`), sorted ascending
/// by `(d, idx)`.
fn top_k(row: &[f32], skip: usize, k: usize) -> Vec<(f32, u32)> {
    let mut out: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
    for (j, &d) in row.iter().enumerate() {
        if j == skip {
            continue;
        }
        let j = j as u32;
        if out.len() == k {
            let (wd, wj) = out[k - 1];
            if !closer(d, j, wd, wj) {
                continue;
            }
        }
        let pos = out.partition_point(|&(pd, pj)| closer(pd, pj, d, j));
        out.insert(pos, (d, j));
        out.truncate(k);
    }
    out
}

/// Rank (1-based, self excluded) of point `j` in `row` under `(d, idx)`.
fn rank_of(row: &[f32], skip: usize, j: usize) -> usize {
    let dj = row[j];
    let mut count = 0usize;
    for (l, &d) in row.iter().enumerate() {
        if l == skip || l == j {
            continue;
        }
        if closer(d, l as u32, dj, j as u32) {
            count += 1;
        }
    }
    count + 1
}

/// All four partial statistics for one anchor. `ld_row` is caller
/// scratch (reused across a shard's anchors).
fn anchor_stats(
    anchor: usize,
    hd_row: &[f32],
    y: &Matrix,
    estimated_hd: &NeighborTable,
    k: usize,
    k_hd: usize,
    ld_row: &mut Vec<f32>,
) -> AnchorStats {
    let n = y.n();
    ld_row.clear();
    let ya = y.row(anchor);
    ld_row.extend((0..n).map(|j| sqdist(ya, y.row(j))));
    let hd_top = top_k(hd_row, anchor, k);
    let ld_top = top_k(ld_row, anchor, k);
    let mut s = AnchorStats::default();
    for &(_, j) in &ld_top {
        if hd_top.iter().any(|&(_, t)| t == j) {
            s.hits += 1;
        } else {
            // An intruder: it ranks strictly beyond k in HD.
            s.trust_pen += (rank_of(hd_row, anchor, j as usize) - k) as u64;
        }
    }
    for &(_, j) in &hd_top {
        if !ld_top.iter().any(|&(_, t)| t == j) {
            s.cont_pen += (rank_of(ld_row, anchor, j as usize) - k) as u64;
        }
    }
    for &(_, j) in hd_top.iter().take(k_hd) {
        if estimated_hd.contains(anchor, j) {
            s.hits_hd += 1;
        }
    }
    s
}

impl QualityProbe {
    /// Sample `cfg.anchors` anchors from `x` (seeded) and compute their
    /// ground-truth HD distance rows.
    pub fn new(x: &Matrix, cfg: ProbeConfig) -> QualityProbe {
        let n = x.n();
        let count = cfg.anchors.max(1).min(n);
        let mut rng = Rng::new(cfg.seed ^ 0x9E37_79B9_7F4A_7C15);
        let ids: Vec<u32> =
            rng.sample_indices(n, count).into_iter().map(|i| i as u32).collect();
        QualityProbe::with_anchors(x, ids, cfg)
    }

    /// Build over an explicit anchor set (tests, rebuild-after-dynamics
    /// verification). Out-of-range ids are dropped; the set is sorted
    /// and deduplicated, so the *sampling order never matters*.
    pub fn with_anchors(x: &Matrix, mut ids: Vec<u32>, cfg: ProbeConfig) -> QualityProbe {
        let n = x.n();
        ids.retain(|&j| (j as usize) < n);
        ids.sort_unstable();
        ids.dedup();
        let pool = WorkerPool::new(cfg.threads.max(1));
        let mut rows: Vec<Vec<f32>> = vec![Vec::new(); ids.len()];
        let ranges = shard_ranges(ids.len(), pool.threads());
        let ids_ref = &ids;
        let mut tasks = Vec::with_capacity(ranges.len());
        let mut rest = rows.as_mut_slice();
        for range in ranges {
            let (chunk, tail) = rest.split_at_mut(range.len().min(rest.len()));
            rest = tail;
            let start = range.start;
            tasks.push(move || {
                for (slot, row) in chunk.iter_mut().enumerate() {
                    let a = ids_ref[start + slot] as usize;
                    let xa = x.row(a);
                    *row = (0..n).map(|j| sqdist(xa, x.row(j))).collect();
                }
            });
        }
        pool.run_tasks(tasks);
        QualityProbe { cfg, anchors: ids, rows, pool }
    }

    /// The live anchor indices (sorted ascending).
    pub fn anchors(&self) -> &[u32] {
        &self.anchors
    }

    /// Measure the current embedding `y` and the engine's estimated HD
    /// table. `None` when the probe is degenerate (no anchors left, or
    /// fewer than 3 points). Read-only and bitwise-deterministic at any
    /// thread count.
    pub fn measure(
        &self,
        y: &Matrix,
        estimated_hd: &NeighborTable,
        iter: usize,
    ) -> Option<QualityReport> {
        let n = y.n();
        let a = self.anchors.len();
        if a == 0 || n < 3 {
            return None;
        }
        debug_assert!(self.rows.iter().all(|r| r.len() == n), "probe rows unpatched");
        let k = self.cfg.k.min(n.saturating_sub(2)).max(1);
        // NeighborTable::new asserts k >= 1, so this is belt-and-braces
        // against a 0/0 in the recall denominator.
        let k_hd = k.min(estimated_hd.k()).max(1);
        let mut per = vec![AnchorStats::default(); a];
        let ranges = shard_ranges(a, self.pool.threads());
        let anchors = &self.anchors;
        let rows = &self.rows;
        let mut tasks = Vec::with_capacity(ranges.len());
        let mut rest = per.as_mut_slice();
        for range in ranges {
            let (chunk, tail) = rest.split_at_mut(range.len().min(rest.len()));
            rest = tail;
            let start = range.start;
            tasks.push(move || {
                let mut ld_row: Vec<f32> = Vec::with_capacity(n);
                for (slot, stat) in chunk.iter_mut().enumerate() {
                    let idx = start + slot;
                    *stat = anchor_stats(
                        anchors[idx] as usize,
                        &rows[idx],
                        y,
                        estimated_hd,
                        k,
                        k_hd,
                        &mut ld_row,
                    );
                }
            });
        }
        self.pool.run_tasks(tasks);
        // Exact-integer fold in anchor (index) order: order- and
        // shard-invariant by construction.
        let (mut hits, mut hits_hd, mut trust_pen, mut cont_pen) = (0u64, 0u64, 0u64, 0u64);
        for s in &per {
            hits += s.hits;
            hits_hd += s.hits_hd;
            trust_pen += s.trust_pen;
            cont_pen += s.cont_pen;
        }
        // Venna–Kaski normalisation by the maximum achievable penalty
        // per query. For k < n/2 all k slots can be intruders with the
        // worst ranks (n−k..n−1), giving k·(2n−3k−1)/2; for k ≥ n/2
        // only the n−1−k points beyond rank k can intrude, giving
        // (n−k)·(n−k−1)/2. With k ≤ n−2 both are ≥ 1, so the metrics
        // land in [0, 1] for every dataset size — the single-case
        // formula would go negative (or degenerate) for k ≥ n/2.
        let max_pen = if 2 * k < n {
            k as f64 * (2.0 * n as f64 - 3.0 * k as f64 - 1.0) / 2.0
        } else {
            let nk = (n - k) as f64;
            nk * (nk - 1.0) / 2.0
        };
        let denom = a as f64 * max_pen;
        let trustworthiness = 1.0 - trust_pen as f64 / denom;
        let continuity = 1.0 - cont_pen as f64 / denom;
        Some(QualityReport {
            iter,
            anchors: a,
            k,
            knn_recall: hits as f64 / (a * k) as f64,
            trustworthiness,
            continuity,
            knn_recall_hd: hits_hd as f64 / (a * k_hd) as f64,
        })
    }

    // --- dynamic-dataset patches (call AFTER the data matrix mutated) --

    /// A point was appended (index `x.n() - 1`): extend every anchor row.
    pub fn push_point(&mut self, x: &Matrix) {
        let new = x.n() - 1;
        let xn = x.row(new);
        for (a, row) in self.anchors.iter().zip(self.rows.iter_mut()) {
            row.push(sqdist(x.row(*a as usize), xn));
        }
    }

    /// Point `gone` was swap-removed (the old last point now has index
    /// `gone`). Drops `gone` from the anchor set if present (anchor
    /// attrition — see the module docs), renames the moved anchor, and
    /// patches every row with the same swap-remove.
    pub fn swap_remove_point(&mut self, gone: usize, x: &Matrix) {
        let old_last = x.n(); // after removal: old n - 1 == new n
        if let Ok(pos) = self.anchors.binary_search(&(gone as u32)) {
            self.anchors.remove(pos);
            self.rows.remove(pos);
        }
        if gone != old_last {
            if let Ok(pos) = self.anchors.binary_search(&(old_last as u32)) {
                // old_last is the largest id → last element; re-insert
                // under its new name to keep the set sorted.
                let row = self.rows.remove(pos);
                self.anchors.remove(pos);
                let at = self.anchors.partition_point(|&a| a < gone as u32);
                self.anchors.insert(at, gone as u32);
                self.rows.insert(at, row);
            }
        }
        for row in self.rows.iter_mut() {
            row.swap_remove(gone);
        }
    }

    /// Point `moved` got new HD coordinates: rescore its column in every
    /// row, and its whole row if it is itself an anchor.
    pub fn move_point(&mut self, moved: usize, x: &Matrix) {
        if let Ok(pos) = self.anchors.binary_search(&(moved as u32)) {
            let xm = x.row(moved);
            let row = &mut self.rows[pos];
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = sqdist(xm, x.row(j));
            }
        }
        for (a, row) in self.anchors.iter().zip(self.rows.iter_mut()) {
            row[moved] = sqdist(x.row(*a as usize), x.row(moved));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;
    use crate::util::proptest as pt;

    fn cfg(k: usize, threads: usize) -> ProbeConfig {
        ProbeConfig { anchors: 256, k, seed: 0, threads }
    }

    /// The hand-computed n=5 fixture: x = 0,1,2,3,4 on a line; the
    /// embedding swaps the last two points. With k = 2 and all points
    /// as anchors:
    ///   recall@2          = (1 + 1 + 0.5 + 1 + 1)/5 = 0.9
    ///   trustworthiness   = 1 − 2·2/(5·2·(10−6−1))  = 13/15
    ///   continuity        = 1 − 2·2/(5·2·(10−6−1))  = 13/15
    /// (anchor 2's LD set {1,4} has intruder 4 at HD rank 4 → penalty 2;
    /// its HD set {1,3} misses 3 at LD rank 4 → penalty 2.)
    fn fixture() -> (Matrix, Matrix) {
        let x = Matrix::from_vec(vec![0.0, 1.0, 2.0, 3.0, 4.0], 5, 1).unwrap();
        let y = Matrix::from_vec(vec![0.0, 1.0, 2.0, 4.0, 3.0], 5, 1).unwrap();
        (x, y)
    }

    #[test]
    fn hand_computed_trust_continuity_recall() {
        let (x, y) = fixture();
        let probe = QualityProbe::with_anchors(&x, vec![0, 1, 2, 3, 4], cfg(2, 1));
        let truth = brute_knn(&x, 2);
        let q = probe.measure(&y, &truth, 7).unwrap();
        assert_eq!((q.iter, q.anchors, q.k), (7, 5, 2));
        assert!((q.knn_recall - 0.9).abs() < 1e-12, "recall {}", q.knn_recall);
        assert!(
            (q.trustworthiness - 13.0 / 15.0).abs() < 1e-12,
            "trust {}",
            q.trustworthiness
        );
        assert!((q.continuity - 13.0 / 15.0).abs() < 1e-12, "cont {}", q.continuity);
        // The estimated table here IS the ground truth.
        assert!((q.knn_recall_hd - 1.0).abs() < 1e-12);
    }

    #[test]
    fn identity_embedding_scores_one() {
        let ds = datasets::blobs(80, 5, 3, 0.5, 8.0, 3);
        let probe = QualityProbe::new(&ds.x, ProbeConfig { anchors: 40, ..cfg(10, 1) });
        let truth = brute_knn(&ds.x, 10);
        let q = probe.measure(&ds.x, &truth, 1).unwrap();
        assert!((q.knn_recall - 1.0).abs() < 1e-12);
        assert!((q.trustworthiness - 1.0).abs() < 1e-12);
        assert!((q.continuity - 1.0).abs() < 1e-12);
        assert!((q.knn_recall_hd - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tiny_datasets_stay_in_range_even_with_large_k() {
        // k ≥ n/2 invalidates the single-case Venna–Kaski normaliser
        // (n = 8, k = 5 makes 2n−3k−1 = 0); the two-case max-penalty
        // form keeps every metric in [0, 1] and never degenerates to a
        // constant perfect score.
        let x = Matrix::from_vec((0..8).map(|v| v as f32).collect(), 8, 1).unwrap();
        let truth = brute_knn(&x, 5);
        let probe = QualityProbe::with_anchors(&x, (0..8).collect(), cfg(5, 1));
        let mut rng = Rng::new(31);
        let mut saw_imperfect = false;
        for _ in 0..8 {
            let y = Matrix::from_vec(pt::gauss_mat(&mut rng, 8, 1, 1.0), 8, 1).unwrap();
            let q = probe.measure(&y, &truth, 1).unwrap();
            for v in [q.knn_recall, q.trustworthiness, q.continuity, q.knn_recall_hd] {
                assert!((0.0..=1.0).contains(&v), "metric out of [0,1]: {v}");
            }
            if q.trustworthiness < 1.0 || q.continuity < 1.0 {
                saw_imperfect = true;
            }
        }
        assert!(saw_imperfect, "random embeddings never produced a rank penalty");
        let q = probe.measure(&x, &truth, 1).unwrap();
        assert!((q.trustworthiness - 1.0).abs() < 1e-12);
        assert!((q.continuity - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_estimated_table_scores_zero_hd_recall() {
        let (x, y) = fixture();
        let probe = QualityProbe::with_anchors(&x, vec![0, 1, 2, 3, 4], cfg(2, 1));
        let empty = NeighborTable::new(5, 2);
        let q = probe.measure(&y, &empty, 1).unwrap();
        assert_eq!(q.knn_recall_hd, 0.0);
    }

    #[test]
    fn anchor_sampling_order_is_irrelevant() {
        let ds = datasets::blobs(120, 6, 3, 0.6, 8.0, 5);
        let mut rng = Rng::new(8);
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, 120, 2, 1.0), 120, 2).unwrap();
        let est = brute_knn(&ds.x, 6);
        let sorted: Vec<u32> = (0..40).map(|i| i * 3).collect();
        let mut shuffled = sorted.clone();
        rng.shuffle(&mut shuffled);
        let a = QualityProbe::with_anchors(&ds.x, sorted, cfg(10, 1));
        let b = QualityProbe::with_anchors(&ds.x, shuffled, cfg(10, 1));
        let (qa, qb) = (a.measure(&y, &est, 1).unwrap(), b.measure(&y, &est, 1).unwrap());
        assert_reports_bitwise_equal(&qa, &qb);
    }

    #[test]
    fn bitwise_identical_across_thread_counts() {
        let ds = datasets::blobs(300, 8, 4, 0.7, 10.0, 9);
        let mut rng = Rng::new(4);
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, 300, 2, 1.0), 300, 2).unwrap();
        let est = brute_knn(&ds.x, 6);
        let reports: Vec<QualityReport> = [1usize, 2, 4]
            .iter()
            .map(|&t| {
                let p = QualityProbe::new(&ds.x, ProbeConfig { anchors: 64, ..cfg(10, t) });
                p.measure(&y, &est, 1).unwrap()
            })
            .collect();
        for r in &reports[1..] {
            assert_reports_bitwise_equal(&reports[0], r);
        }
    }

    #[test]
    fn dynamic_patches_match_fresh_rebuild() {
        let base = datasets::blobs(80, 5, 3, 0.5, 8.0, 11);
        let mut x = base.x.clone();
        let mut probe = QualityProbe::new(&x, ProbeConfig { anchors: 24, ..cfg(6, 2) });
        // Insert two points.
        let extra = datasets::blobs(2, 5, 1, 0.5, 8.0, 70);
        for r in 0..2 {
            x.push_row(extra.x.row(r));
            probe.push_point(&x);
        }
        // Move one point far away.
        let far = vec![9.0f32; 5];
        x.row_mut(4).copy_from_slice(&far);
        probe.move_point(4, &x);
        // Remove two points (swap-remove semantics), likely hitting an
        // anchor and a moved-into-anchor case across seeds.
        for &gone in &[3usize, 10] {
            x.swap_remove_row(gone);
            probe.swap_remove_point(gone, &x);
        }
        let fresh = QualityProbe::with_anchors(&x, probe.anchors().to_vec(), cfg(6, 1));
        let mut rng = Rng::new(2);
        let y = Matrix::from_vec(pt::gauss_mat(&mut rng, x.n(), 2, 1.0), x.n(), 2).unwrap();
        let est = brute_knn(&x, 6);
        let qa = probe.measure(&y, &est, 5).unwrap();
        let qb = fresh.measure(&y, &est, 5).unwrap();
        assert_reports_bitwise_equal(&qa, &qb);
    }

    #[test]
    fn removing_every_anchor_disables_the_probe() {
        let ds = datasets::blobs(10, 3, 1, 0.5, 4.0, 1);
        let mut x = ds.x.clone();
        let mut probe = QualityProbe::with_anchors(&x, vec![0, 1], cfg(2, 1));
        // Remove points 0 and 1 (anchor attrition down to zero).
        for _ in 0..2 {
            x.swap_remove_row(0);
            probe.swap_remove_point(0, &x);
        }
        // Whatever remains, the anchors referencing removed rows are gone
        // or renamed consistently; if none survive, measure is None.
        if probe.anchors().is_empty() {
            assert!(probe.measure(&x, &NeighborTable::new(x.n(), 2), 1).is_none());
        } else {
            for &a in probe.anchors() {
                assert!((a as usize) < x.n(), "stale anchor {a}");
            }
        }
    }

    fn assert_reports_bitwise_equal(a: &QualityReport, b: &QualityReport) {
        assert_eq!(a.anchors, b.anchors);
        assert_eq!(a.k, b.k);
        assert_eq!(a.knn_recall.to_bits(), b.knn_recall.to_bits(), "recall");
        assert_eq!(
            a.trustworthiness.to_bits(),
            b.trustworthiness.to_bits(),
            "trustworthiness"
        );
        assert_eq!(a.continuity.to_bits(), b.continuity.to_bits(), "continuity");
        assert_eq!(a.knn_recall_hd.to_bits(), b.knn_recall_hd.to_bits(), "hd recall");
    }

    #[test]
    fn top_k_and_rank_agree_on_ties() {
        // Two equidistant candidates: index breaks the tie both in
        // selection and in ranking.
        let row = vec![0.0, 1.0, 1.0, 4.0];
        let top = top_k(&row, 0, 2);
        assert_eq!(top, vec![(1.0, 1), (1.0, 2)]);
        assert_eq!(rank_of(&row, 0, 1), 1);
        assert_eq!(rank_of(&row, 0, 2), 2);
        assert_eq!(rank_of(&row, 0, 3), 3);
    }
}
