//! Embedding-quality metrics: R_NX(K) and its AUC (Lee et al. [23]),
//! pointwise distance correlation and neighbourhood preservation
//! (Fig. 1 colour maps), KNN recall — and the *online* sampled quality
//! probe ([`probe`]) that streams recall / trustworthiness / continuity
//! through the session and server layers during a run.

pub mod pointwise;
pub mod probe;
pub mod rnx;

pub use probe::{ProbeConfig, QualityProbe, QualityReport};
pub use rnx::{rnx_auc, rnx_curve, RnxCurve};
