//! Embedding-quality metrics: R_NX(K) and its AUC (Lee et al. [23]),
//! pointwise distance correlation and neighbourhood preservation
//! (Fig. 1 colour maps), and KNN recall.

pub mod rnx;
pub mod pointwise;

pub use rnx::{rnx_auc, rnx_curve, RnxCurve};
