//! # FUnc-SNE
//!
//! A Rust + JAX/Pallas reproduction of *"FUnc-SNE: A flexible, Fast, and
//! Unconstrained algorithm for neighbour embeddings"* (Lambert, Couplet,
//! Verleysen, Lee — preprint submitted to Neurocomputing, 2024/2025).
//!
//! The crate is a three-layer system:
//!
//! * **Layer 3 (this crate)** — the coordinator: the interleaved
//!   KNN-refinement + gradient-descent loop that is the paper's central
//!   contribution, plus every substrate it needs (synthetic datasets,
//!   exact/approximate KNN, perplexity calibration, quality metrics,
//!   clustering, baselines, a CLI, and a bench harness regenerating every
//!   table and figure of the paper).
//! * **Layer 2 (python/compile/model.py)** — the force/distance compute
//!   graphs written in JAX, lowered once (AOT) to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing
//!   the hot inner loops (heavy-tailed force tiles, squared-distance
//!   tiles), called from the L2 graphs, verified against a pure-jnp
//!   oracle.
//!
//! At run time the Rust binary loads `artifacts/*.hlo.txt` through the
//! PJRT C API (`xla` crate) and never touches Python.

pub mod util;
pub mod config;
pub mod cli;
pub mod data;
pub mod linalg;
pub mod knn;
pub mod hd;
pub mod ld;
pub mod engine;
pub mod baselines;
pub mod metrics;
pub mod cluster;
pub mod runtime;
pub mod coordinator;
pub mod figures;
