//! # FUnc-SNE
//!
//! A Rust + JAX/Pallas reproduction of *"FUnc-SNE: A flexible, Fast, and
//! Unconstrained algorithm for neighbour embeddings"* (Lambert, Couplet,
//! Verleysen, Lee — preprint submitted to Neurocomputing, 2024/2025).
//!
//! ## Session API
//!
//! The public entry point is the [`session`] facade, built for the
//! paper's headline feature: *interactive* optimisation, where any
//! hyperparameter — including HD-side ones — changes between two
//! iterations with instantaneous feedback.
//!
//! ```no_run
//! use funcsne::session::{Command, Session};
//!
//! # fn main() -> anyhow::Result<()> {
//! # let x = funcsne::data::Matrix::zeros(1000, 50);
//! // Fluent construction: validation, optional PCA pre-reduction and
//! // backend selection all live in the builder.
//! let mut session = Session::builder()
//!     .dataset(x)
//!     .ld_dim(2)
//!     .perplexity(30.0)
//!     .backend_name("native")
//!     .snapshot_stride(50)
//!     .build()?;
//!
//! session.run(250)?;
//!
//! // Mid-run steering: typed commands, drained FIFO between
//! // iterations — never reaching into the step loop.
//! session.enqueue(Command::SetAlpha(0.5));
//! session.enqueue(Command::SetPerplexity(60.0));
//! session.run(250)?;
//!
//! let y = session.embedding(); // N × 2
//! # let _ = y; Ok(())
//! # }
//! ```
//!
//! Telemetry flows out through [`session::EventSink`]s and the
//! ring-buffered [`session::SnapshotBuffer`]; many concurrent
//! embeddings are owned and stepped round-robin by a
//! [`session::SessionManager`]. The raw [`engine::FuncSne`] setters are
//! crate-private — the command queue is the supported mutation path
//! (engine state stays readable for metrics and figures; writing those
//! fields directly bypasses the setters' bookkeeping).
//!
//! The same capability is exposed over the wire by the [`server`]
//! module: `funcsne serve` runs a zero-dependency HTTP/JSON service
//! (std-only listener, vendored-shim policy) in which a background
//! stepping thread owns the [`session::SessionManager`] and request
//! handlers reach it through channels — create sessions, steer them
//! mid-run, stream embedding frames, scrape Prometheus metrics.
//!
//! ## Threading model
//!
//! Two orthogonal axes, deliberately kept apart:
//!
//! * **Across sessions** — [`session::Session`] is intentionally
//!   **not** `Send` (event sinks may hold `Rc`s, the PJRT client pins
//!   to a thread). A server scales out by owning one
//!   [`session::SessionManager`] per worker thread and sharding
//!   sessions across them; sessions never migrate between threads.
//! * **Within a session** — the *entire* iteration is parallel, not
//!   just the force pass. The `threads` knob
//!   ([`config::EmbedConfig::threads`], [`session::SessionBuilder::threads`],
//!   CLI `--threads`; `0` = auto-detect, default honours the
//!   `FUNCSNE_THREADS` env var) widens two cooperating pools of scoped
//!   worker threads ([`runtime::WorkerPool`]): [`ld::ParallelBackend`]
//!   shards the force pass, candidate scoring and the gradient/
//!   momentum update behind the [`engine::ComputeBackend`] boundary,
//!   and the engine's own pool shards the per-iteration LD/HD
//!   neighbour refinement and negative sampling. Three disciplines
//!   keep every bit identical at any thread count: (1) all per-point
//!   randomness comes from counter-based [`util::StreamRng`] streams
//!   (`at(seed, iter, point, lane)`) instead of one sequential cursor,
//!   so candidates and samples are pure functions of their
//!   coordinates; (2) each output row is written by exactly one shard
//!   (disjoint row views), with symmetric neighbour inserts applied in
//!   fixed shard-then-point order; (3) f64 reductions (kernel
//!   normaliser, implosion Σy²) fold one per-point subtotal in point
//!   order. An embedding is reproducible from its seed regardless of
//!   `--threads` (enforced by `rust/tests/parity.rs`).
//!
//! ## Architecture
//!
//! The crate is a three-layer system:
//!
//! * **Layer 3 (this crate)** — the coordinator: the interleaved
//!   KNN-refinement + gradient-descent loop that is the paper's central
//!   contribution, plus every substrate it needs (synthetic datasets,
//!   exact/approximate KNN, perplexity calibration, quality metrics,
//!   clustering, baselines, a CLI, and a bench harness regenerating every
//!   table and figure of the paper).
//! * **Layer 2 (python/compile/model.py)** — the force/distance compute
//!   graphs written in JAX, lowered once (AOT) to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing
//!   the hot inner loops (heavy-tailed force tiles, squared-distance
//!   tiles), called from the L2 graphs, verified against a pure-jnp
//!   oracle.
//!
//! At run time the Rust binary loads `artifacts/*.hlo.txt` through the
//! PJRT C API (`xla` crate) and never touches Python.

pub mod util;
pub mod config;
pub mod analysis;
pub mod cli;
pub mod data;
pub mod linalg;
pub mod knn;
pub mod hd;
pub mod ld;
pub mod engine;
pub mod obs;
pub mod persist;
pub mod session;
pub mod server;
pub mod baselines;
pub mod metrics;
pub mod cluster;
pub mod runtime;
pub mod coordinator;
pub mod figures;
