//! The command-line interface: a miniature argument parser (clap is
//! not available offline) plus the subcommand implementations, all
//! running on the [`crate::session`] facade.
//!
//! Grammar: `funcsne <subcommand> [--key value]... [--flag]...`.
//! Keys use kebab-case on the command line and are normalised to
//! snake_case, so `--ld-dim 8` sets `ld_dim`.

use crate::config::toml_lite::{parse_value, Value};
use crate::config::{EmbedConfig, KnnConfig};
use crate::coordinator::driver::{dataset_by_name, default_artifact_dir, run_embedding};
use crate::data::datasets::Dataset;
use crate::data::Matrix;
use crate::figures::common::Scale;
use crate::knn::brute::brute_knn;
use crate::knn::nn_descent::nn_descent;
use crate::metrics::rnx::{rnx_curve, rnx_curve_vs_table};
use crate::server::json::Json;
use crate::server::{Server, ServerConfig};
use crate::session::{Event, Session};
use crate::util::{io, plot};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, bare positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, Value>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let mut out = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let key = key.replace('-', "_");
                // A following token that is not itself an option is the value;
                // otherwise this is a boolean flag.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let raw = it.next().unwrap();
                        let val = parse_value(&raw)
                            .unwrap_or(Value::Str(raw.clone()));
                        out.options.insert(key, val);
                    }
                    _ => {
                        out.options.insert(key, Value::Bool(true));
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        match self.options.get(key) {
            Some(Value::Str(s)) => s.clone(),
            Some(Value::Int(i)) => i.to_string(),
            Some(Value::Float(f)) => f.to_string(),
            Some(Value::Bool(b)) => b.to_string(),
            None => default.to_string(),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("--{key} expects a number")),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            Some(v) => {
                let i = v.as_i64().ok_or_else(|| anyhow::anyhow!("--{key} expects an integer"))?;
                if i < 0 {
                    bail!("--{key} expects a non-negative integer");
                }
                Ok(i as usize)
            }
            None => Ok(default),
        }
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.options.get(key), Some(Value::Bool(true)))
    }

    /// Re-express options as a `section.key` map so config `apply` works.
    pub fn as_section_map(&self, section: &str) -> BTreeMap<String, Value> {
        self.options
            .iter()
            .map(|(k, v)| (format!("{section}.{k}"), v.clone()))
            .collect()
    }
}

// --- subcommand implementations ---------------------------------------

pub const HELP: &str = "\
funcsne — FUnc-SNE: flexible, fast, unconstrained neighbour embeddings

USAGE: funcsne <subcommand> [--key value]...

SUBCOMMANDS
  embed      run an embedding           --dataset NAME --n N [--alpha A]
             [--ld-dim D] [--n-iters I] [--perplexity P]
             [--backend native|simd|pjrt]  force kernels (default env
                            FUNCSNE_BACKEND or native; simd = lane-vectorized,
                            bitwise-reproducible at any thread count)
             [--threads T]  compute-backend worker threads (0 = auto-detect;
                            T > 1 shards the native/simd force and scoring
                            passes; default env FUNCSNE_THREADS or 1)
             [--attraction X] [--repulsion X] [--seed S] [--out results/embed]
  knn        compare KNN finders        --dataset NAME --n N [--k K] [--iters I]
  eval       run to convergence and print the sampled quality trajectory
             as JSON                    --dataset NAME --n N [--iters I]
             [--probe-every P] [--anchors A] [--seed S] [--threads T]
             [--out file.json]  also write the JSON to a file
             [--min-recall R]   exit non-zero if final KNN recall@10 < R
                                (the CI quality gate)
  figure     regenerate paper figures   [--only fig1..fig11|table1|table2] [--full]
  hierarchy  α-sweep hierarchy graph    --dataset NAME --n N [--ld-dim D]
  serve      run the HTTP/JSON service  [--addr 127.0.0.1:7878] [--threads T]
             [--max-sessions N] [--snapshot-every I]
             [--max-streams N] [--max-streams-per-session N]
             [--stream-queue FRAMES] [--keyframe-every K]
             [--trace]  enable latency histograms + span tracing
                        (default env FUNCSNE_TRACE)
             [--state-dir DIR]  durable sessions: checkpoint every
                        session under DIR (snapshot + write-ahead
                        command log) and restore them all at boot;
                        SIGTERM/SIGINT checkpoints then exits cleanly
             [--checkpoint-every I]  snapshot a running durable session
                        after I iterations of progress (default 500;
                        0 = only on pause/delete/shutdown/demand)
             REST surface: POST /sessions, POST /sessions/:id/commands,
             GET /sessions/:id/embedding[?iter=N], GET /sessions/:id/stats,
             GET /sessions/:id/stream (chunked binary frames),
             POST /sessions/:id/checkpoint, DELETE /sessions/:id,
             GET /healthz, GET /metrics,
             GET /debug/trace (Chrome trace-event JSON)
  checkpoint run an embedding offline and write its durable image
             (snapshot + WAL) as `serve --state-dir` would
             --dataset NAME --n N [--iters I] [--state-dir DIR] [--id K]
             [--seed S] [--threads T]
  restore    bring a checkpointed session back from disk (snapshot +
             WAL replay), optionally continue it, and export the result
             [--state-dir DIR] [--id K] [--iters EXTRA] [--out file.npy]
  trace      capture spans from a running server (started with --trace)
             [--addr 127.0.0.1:7878] [--sweeps N] [--out trace.json]
             [--timeout SECONDS]  waits until N sweeps elapse, then
             saves GET /debug/trace for Perfetto / chrome://tracing
  lint       run the determinism/concurrency lint over the crate source
             [--root rust/src] [--config lint.toml]  exit non-zero on
             any finding not waived in lint.toml (the CI hard gate)
  info       show artifact menu / platform

Datasets: scurve scurve_unbalanced blobs blobs_overlap blobs_disjoint coil
          mnist rat_brain tabula deep_features nested
          (or --data path.npy / --data path.csv to load a file)
";

/// Dispatch a parsed command line.
pub fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "embed" => cmd_embed(args),
        "knn" => cmd_knn(args),
        "eval" => cmd_eval(args),
        "figure" | "figures" => cmd_figure(args),
        "hierarchy" => cmd_hierarchy(args),
        "serve" => cmd_serve(args),
        "checkpoint" => cmd_checkpoint(args),
        "restore" => cmd_restore(args),
        "trace" => cmd_trace(args),
        "lint" => cmd_lint(args),
        "info" => cmd_info(),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    // `--data path.npy` / `--data path.csv` loads a file instead of a
    // named synthetic dataset (labels default to a single class).
    let data_path = args.get_str("data", "");
    if !data_path.is_empty() {
        let (data, n, d) = io::read_matrix_f32(std::path::Path::new(&data_path))?;
        let x = Matrix::from_vec(data, n, d)?;
        return Ok(Dataset {
            name: data_path,
            x,
            labels: vec![0; n],
            coarse_labels: None,
            hierarchy: None,
        });
    }
    let name = args.get_str("dataset", "blobs");
    let n = args.get_usize("n", 2000)?;
    let seed = args.get_usize("seed", 42)? as u64;
    dataset_by_name(&name, n, seed)
}

fn cmd_embed(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let mut cfg = EmbedConfig {
        alpha: args.get_f64("alpha", 1.0)?,
        ld_dim: args.get_usize("ld_dim", 2)?,
        n_iters: args.get_usize("n_iters", 1000)?,
        seed: args.get_usize("seed", 42)? as u64,
        ..EmbedConfig::default()
    };
    // An explicit --backend wins; otherwise the EmbedConfig default
    // stands (which itself honours FUNCSNE_BACKEND, then "native").
    if args.options.contains_key("backend") {
        cfg.backend = args.get_str("backend", "native").parse()?;
    }
    cfg.perplexity = args.get_f64("perplexity", cfg.perplexity)?;
    cfg.attraction = args.get_f64("attraction", cfg.attraction)?;
    cfg.repulsion = args.get_f64("repulsion", cfg.repulsion)?;
    cfg.lr = args.get_f64("lr", cfg.lr)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    cfg.k_hd = args.get_usize("k_hd", cfg.k_hd)?.min(ds.n() - 1);
    cfg.k_ld = args.get_usize("k_ld", cfg.k_ld)?.min(ds.n() - 1);
    cfg.perplexity = cfg.perplexity.min(cfg.k_hd as f64);
    println!(
        "embedding {} (n={}, d={} → {}), α={}, backend {:?}, threads {}",
        ds.name,
        ds.n(),
        ds.d(),
        cfg.ld_dim,
        cfg.alpha,
        cfg.backend,
        cfg.resolved_threads()
    );
    // `run_embedding` is a thin wrapper over the session facade; the
    // report hands the session back for inspection. PCA pre-reduction
    // of wide data goes through the builder so the session retains the
    // fitted basis (dynamic commands keep accepting original-dim rows).
    let report = run_embedding(ds.x.clone(), &cfg, &default_artifact_dir(), Some(64))?;
    let y = report.session.embedding();
    println!(
        "done in {:.2}s ({:.1} iters/s, {} HD refreshes, {} σ recalibrations)",
        report.seconds,
        report.iters_per_sec,
        report.session.stats().hd_refines,
        report.session.stats().recalibrated_points
    );
    if ds.n() <= 4000 {
        let c = rnx_curve(&ds.x, y, 50.min(ds.n() - 2));
        println!("R_NX AUC = {:.3}", c.auc);
    }
    if cfg.ld_dim == 2 {
        println!(
            "{}",
            plot::scatter_2d("embedding", y.data(), &ds.labels, ds.n(), 78, 22)
        );
    }
    let out = args.get_str("out", "results/embed");
    io::write_npy_f32(
        std::path::Path::new(&format!("{out}.npy")),
        y.data(),
        &[y.n(), y.d()],
    )?;
    println!("wrote {out}.npy");
    Ok(())
}

fn cmd_knn(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let k = args.get_usize("k", 16)?;
    let iters = args.get_usize("iters", 300)?;
    println!("exact ground truth (n={}, k={k})...", ds.n());
    let truth = brute_knn(&ds.x, k);
    println!("NN-descent...");
    let nnd = nn_descent(&ds.x, &KnnConfig { k, rho: 0.8, ..KnnConfig::default() });
    let c1 = rnx_curve_vs_table(&truth, &nnd.table, k);
    println!("proposed iterative finder ({iters} engine iterations)...");
    let mut cfg = crate::figures::common::figure_config(ds.n(), 2, 1.0);
    cfg.k_hd = k.max(8);
    cfg.refine_base_prob = 1.0;
    let mut session = Session::builder().dataset(ds.x.clone()).config(cfg).build()?;
    session.run(iters)?;
    let c2 = rnx_curve_vs_table(&truth, &session.engine().knn.hd, k);
    println!(
        "R_NX AUC: nn-descent {:.3} ({} dist evals) | proposed {:.3}",
        c1.auc, nnd.dist_evals, c2.auc
    );
    Ok(())
}

/// `eval`: run a dataset to convergence with the online quality probe
/// on, print the quality trajectory as JSON, and optionally gate on a
/// committed recall floor (the CI `quality-gate` job).
fn cmd_eval(args: &Args) -> Result<()> {
    use std::cell::RefCell;
    use std::rc::Rc;
    let ds = load_dataset(args)?;
    let n = ds.n();
    if n < 4 {
        bail!("eval needs at least 4 points (got {n})");
    }
    let iters = args.get_usize("iters", 300)?;
    let probe_every = args.get_usize("probe_every", 25)?;
    if probe_every == 0 {
        // 0 means "probe off" everywhere else; an eval without a probe
        // has nothing to report, so reject rather than silently coerce.
        bail!("--probe-every must be >= 1 (eval IS the probe; use `embed` to run without one)");
    }
    // Clamp to N here (the probe clamps identically) so the reported
    // anchor count matches what actually ran.
    let anchors = args.get_usize("anchors", 256)?.max(1).min(n);
    let mut cfg = EmbedConfig {
        seed: args.get_usize("seed", 42)? as u64,
        n_iters: iters,
        probe_every,
        probe_anchors: anchors,
        ..EmbedConfig::default()
    };
    cfg.alpha = args.get_f64("alpha", cfg.alpha)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    cfg.k_hd = args.get_usize("k_hd", cfg.k_hd)?.min(n - 1);
    cfg.k_ld = args.get_usize("k_ld", cfg.k_ld)?.min(n - 1);
    cfg.perplexity = args.get_f64("perplexity", cfg.perplexity)?.min(cfg.k_hd as f64);
    let mut session = Session::builder().dataset(ds.x.clone()).config(cfg).build()?;
    let trajectory: Rc<RefCell<Vec<Json>>> = Rc::new(RefCell::new(Vec::new()));
    let tap = Rc::clone(&trajectory);
    session.add_sink(Box::new(move |e: &Event| {
        if let Event::Quality { iter, recall, trust, cont, knn_recall_hd } = e {
            tap.borrow_mut().push(Json::obj(vec![
                ("iter", (*iter).into()),
                ("knn_recall", (*recall).into()),
                ("trustworthiness", (*trust).into()),
                ("continuity", (*cont).into()),
                ("knn_recall_hd", (*knn_recall_hd).into()),
            ]));
        }
    }));
    session.run(iters)?;
    let final_q = session.quality().copied();
    let final_json = match &final_q {
        None => Json::Null,
        Some(q) => Json::obj(vec![
            ("iter", q.iter.into()),
            ("anchors", q.anchors.into()),
            ("k", q.k.into()),
            ("knn_recall", q.knn_recall.into()),
            ("trustworthiness", q.trustworthiness.into()),
            ("continuity", q.continuity.into()),
            ("knn_recall_hd", q.knn_recall_hd.into()),
        ]),
    };
    let doc = Json::obj(vec![
        ("dataset", ds.name.as_str().into()),
        ("n", n.into()),
        ("iters", iters.into()),
        ("probe_every", probe_every.into()),
        ("anchors", anchors.into()),
        ("trajectory", Json::Arr(trajectory.borrow().clone())),
        ("final", final_json),
    ]);
    let text = doc.encode();
    let out = args.get_str("out", "");
    if !out.is_empty() {
        std::fs::write(&out, &text)?;
        eprintln!("wrote {out}");
    }
    println!("{text}");
    let floor = args.get_f64("min_recall", 0.0)?;
    match final_q {
        Some(q) if q.knn_recall >= floor => Ok(()),
        Some(q) => bail!(
            "quality gate FAILED: final knn_recall {:.4} < committed floor {floor}",
            q.knn_recall
        ),
        None if floor > 0.0 => bail!(
            "quality gate FAILED: no probe report produced \
             (iters {iters} < probe_every {probe_every}?)"
        ),
        None => Ok(()),
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let scale = if args.get_flag("full") { Scale::Full } else { Scale::from_env() };
    let only = args.get_str("only", "all");
    type Driver = fn(Scale) -> Result<String>;
    let all: Vec<(&str, Driver)> = vec![
        ("fig1", crate::figures::fig1::run),
        ("fig2", crate::figures::fig2::run),
        ("fig3", crate::figures::fig3::run),
        ("fig4", crate::figures::fig4::run),
        ("fig5", crate::figures::fig5::run),
        ("fig6", crate::figures::fig6::run),
        ("fig7", crate::figures::fig7::run),
        ("fig8", crate::figures::fig8::run),
        ("fig9_10", crate::figures::fig9_10::run),
        ("fig11", crate::figures::fig11::run),
        ("table1", crate::figures::table1::run),
        ("table2", crate::figures::table2::run),
    ];
    let mut ran = 0;
    for (name, f) in all {
        if only == "all" || only == name {
            println!(">>> {name}");
            f(scale)?;
            ran += 1;
        }
    }
    if ran == 0 {
        bail!("no figure matched {only:?}");
    }
    Ok(())
}

fn cmd_hierarchy(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let ld_dim = args.get_usize("ld_dim", 4)?;
    let mut cfg = crate::figures::common::figure_config(ds.n(), ld_dim, 1.0);
    cfg.n_iters = 0;
    let mut engine = crate::engine::FuncSne::new(ds.x.clone(), cfg)?;
    let mut backend = crate::ld::NativeBackend::new();
    let sweep = crate::cluster::hierarchy::SweepConfig {
        iters_per_level: args.get_usize("iters_per_level", 300)?,
        ..Default::default()
    };
    let graph = crate::cluster::hierarchy::alpha_sweep(&mut engine, &mut backend, &sweep)?;
    let pos = crate::cluster::layout::layout(&graph, 250, 1);
    println!(
        "{}",
        crate::cluster::layout::render_ascii(&graph, &pos, 70, 20)
    );
    Ok(())
}

/// SIGTERM/SIGINT → graceful shutdown without any signal crate: a
/// minimal `signal(2)` binding whose handler does the one thing a
/// handler safely can — set an atomic flag — watched by an ordinary
/// thread that fires the server's shutdown handle. The server then
/// drains in-flight requests, checkpoints every durable session and
/// pushes a final keyframe to stream subscribers before `run` returns.
#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Set from the signal handler; read by the watcher thread.
    static REQUESTED: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        /// POSIX `signal(2)`; handler addresses travel as `usize`.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(_signum: i32) {
        // Only an atomic store: the one async-signal-safe action.
        REQUESTED.store(true, Ordering::SeqCst);
    }

    /// Install handlers for SIGINT and SIGTERM.
    pub fn install() {
        // SAFETY: `signal` is the POSIX C function with this exact
        // signature; `on_signal` has the required `extern "C" fn(i32)`
        // ABI and performs only an async-signal-safe atomic store.
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }

    /// Has a termination signal arrived since [`install`]?
    pub fn requested() -> bool {
        REQUESTED.load(Ordering::SeqCst)
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    crate::persist::failpoint::init_from_env();
    let defaults = ServerConfig::default();
    let state_dir = args.get_str("state_dir", "");
    let cfg = ServerConfig {
        addr: args.get_str("addr", "127.0.0.1:7878"),
        threads: args.get_usize("threads", 4)?,
        max_sessions: args.get_usize("max_sessions", 64)?,
        snapshot_every: args.get_usize("snapshot_every", 25)?,
        max_streams: args.get_usize("max_streams", defaults.max_streams)?,
        max_streams_per_session: args
            .get_usize("max_streams_per_session", defaults.max_streams_per_session)?,
        stream_queue: args.get_usize("stream_queue", defaults.stream_queue)?,
        keyframe_every: args.get_usize("keyframe_every", defaults.keyframe_every)?,
        // `--trace` turns observability on; absent, the FUNCSNE_TRACE
        // env default (already folded into `defaults`) decides.
        trace: args.get_flag("trace") || defaults.trace,
        state_dir: (!state_dir.is_empty()).then(|| std::path::PathBuf::from(&state_dir)),
        checkpoint_every: args.get_usize("checkpoint_every", defaults.checkpoint_every)?,
    };
    let durable = cfg.state_dir.is_some();
    let server = Server::bind(cfg)?;
    let addr = server.local_addr();
    println!("funcsne service listening on http://{addr}");
    println!("  create:  curl -s -X POST {addr}/sessions -d '{{\"rows\": [[...], ...]}}'");
    println!("  steer:   curl -s -X POST {addr}/sessions/0/commands \\");
    println!("                -d '{{\"command\": \"set_alpha\", \"value\": 0.5}}'");
    println!("  fetch:   curl -s {addr}/sessions/0/embedding");
    println!("  stream:  curl -sN {addr}/sessions/0/stream -o frames.bin");
    println!("  health:  curl -s {addr}/healthz   ·   metrics: curl -s {addr}/metrics");
    if durable {
        println!("  durable: sessions persist in {state_dir} and restore at boot");
    }
    #[cfg(unix)]
    {
        signals::install();
        let handle = server.handle();
        std::thread::spawn(move || loop {
            if signals::requested() {
                eprintln!("funcsne: signal received; checkpointing sessions and shutting down");
                handle.shutdown();
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        });
    }
    server.run()
}

/// `checkpoint`: run an embedding offline for `--iters` iterations,
/// then publish its durable image (snapshot + empty WAL) under
/// `--state-dir`, exactly as `serve --state-dir` would — a way to
/// produce or refresh state files and exercise the durability layer
/// end to end without a server.
fn cmd_checkpoint(args: &Args) -> Result<()> {
    crate::persist::failpoint::init_from_env();
    let ds = load_dataset(args)?;
    if ds.n() < 2 {
        bail!("checkpoint needs at least 2 points (got {})", ds.n());
    }
    let iters = args.get_usize("iters", 300)?;
    let id = args.get_usize("id", 0)? as u64;
    let state_dir = std::path::PathBuf::from(args.get_str("state_dir", "state"));
    std::fs::create_dir_all(&state_dir)?;
    let mut cfg = EmbedConfig {
        seed: args.get_usize("seed", 42)? as u64,
        n_iters: iters,
        ..EmbedConfig::default()
    };
    cfg.alpha = args.get_f64("alpha", cfg.alpha)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    cfg.k_hd = args.get_usize("k_hd", cfg.k_hd)?.min(ds.n() - 1);
    cfg.k_ld = args.get_usize("k_ld", cfg.k_ld)?.min(ds.n() - 1);
    cfg.perplexity = args.get_f64("perplexity", cfg.perplexity)?.min(cfg.k_hd as f64);
    let mut session = Session::builder().dataset(ds.x.clone()).config(cfg).build()?;
    session.run(iters)?;
    let paths = crate::persist::session_paths(&state_dir, id);
    let bytes = crate::persist::checkpoint_session(&mut session, &paths)?;
    println!(
        "checkpointed session-{id} at iteration {} ({bytes} bytes) under {}",
        session.iterations(),
        state_dir.display()
    );
    Ok(())
}

/// `restore`: bring a checkpointed session back from `--state-dir`
/// (snapshot load + WAL-tail replay — the same path the server's boot
/// restore takes), optionally run it further, and export the result.
fn cmd_restore(args: &Args) -> Result<()> {
    crate::persist::failpoint::init_from_env();
    let id = args.get_usize("id", 0)? as u64;
    let state_dir = std::path::PathBuf::from(args.get_str("state_dir", "state"));
    let paths = crate::persist::session_paths(&state_dir, id);
    let restored = crate::persist::restore_session(&paths, &default_artifact_dir())?;
    let mut session = restored.session;
    if let Some(w) = &restored.wal_warning {
        eprintln!("warning: {w}");
    }
    println!(
        "restored session-{id} at iteration {} ({} logged command(s) replayed)",
        session.iterations(),
        restored.replayed
    );
    let extra = args.get_usize("iters", 0)?;
    if extra > 0 {
        session.run(extra)?;
        println!("ran {extra} further iteration(s) → iteration {}", session.iterations());
    }
    let out = args.get_str("out", "");
    if !out.is_empty() {
        let y = session.embedding();
        io::write_npy_f32(std::path::Path::new(&out), y.data(), &[y.n(), y.d()])?;
        println!("wrote {out}");
    }
    Ok(())
}

/// Minimal one-shot HTTP GET for [`cmd_trace`]: one request per
/// connection (`Connection: close`), so the whole response is "read
/// to EOF". Returns (status, body).
fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    use anyhow::Context;
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connect {addr}"))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
        .with_context(|| format!("send request to {addr}"))?;
    let mut raw = Vec::new();
    stream
        .read_to_end(&mut raw)
        .with_context(|| format!("read response from {addr}"))?;
    let text = String::from_utf8_lossy(&raw);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response from {addr}"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("missing status line in response from {addr}"))?;
    Ok((status, body.to_string()))
}

/// `trace`: capture span data covering N sweeps from a running server
/// and write it as Chrome trace-event JSON (loadable in Perfetto or
/// chrome://tracing). The server must have tracing enabled
/// (`serve --trace` or FUNCSNE_TRACE=1); we poll `/healthz` until the
/// sweep counter advances by `--sweeps`, then snapshot `/debug/trace`.
fn cmd_trace(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let sweeps = args.get_usize("sweeps", 50)? as u64;
    let out = args.get_str("out", "trace.json");
    let timeout_s = args.get_f64("timeout", 30.0)?;
    let sweeps_now = |body: &str| -> Result<u64> {
        let j = crate::server::json::parse(body)?;
        j.get("sweeps")
            .and_then(Json::as_usize)
            .map(|s| s as u64)
            .ok_or_else(|| anyhow::anyhow!("/healthz reply has no \"sweeps\" counter"))
    };
    let (status, body) = http_get(&addr, "/healthz")?;
    if status != 200 {
        bail!("GET {addr}/healthz returned {status}");
    }
    let start_sweeps = sweeps_now(&body)?;
    eprintln!("connected to {addr} (sweep {start_sweeps}); capturing {sweeps} sweep(s)...");
    // cli is not wall_clock-lint scope, but PhaseClock keeps every
    // timing read in the repo on the one sanctioned shim.
    let clock = crate::util::timer::PhaseClock::start();
    loop {
        let (status, body) = http_get(&addr, "/healthz")?;
        if status != 200 {
            bail!("GET {addr}/healthz returned {status}");
        }
        if sweeps_now(&body)? >= start_sweeps + sweeps {
            break;
        }
        if clock.elapsed_ns() as f64 / 1e9 > timeout_s {
            bail!(
                "timed out after {timeout_s}s waiting for {sweeps} sweep(s); \
                 is a session running? (POST {addr}/sessions)"
            );
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let (status, body) = http_get(&addr, "/debug/trace")?;
    if status != 200 {
        bail!("GET {addr}/debug/trace returned {status}");
    }
    // Round-trip through the in-repo codec: validates the payload and
    // re-encodes it canonically before it lands on disk.
    let doc = crate::server::json::parse(&body)?;
    let enabled = doc
        .get("otherData")
        .and_then(|o| o.get("enabled"))
        .and_then(Json::as_bool)
        .unwrap_or(false);
    if !enabled {
        eprintln!("note: server tracing is OFF (start it with `serve --trace` or FUNCSNE_TRACE=1)");
    }
    let events = doc.get("traceEvents").and_then(Json::as_arr).map_or(0, <[Json]>::len);
    std::fs::write(&out, doc.encode())?;
    println!("wrote {out} ({events} events); open it at https://ui.perfetto.dev");
    Ok(())
}

/// `lint`: the self-hosted determinism/concurrency checks of
/// [`crate::analysis`], run over the crate's own source tree. Exit
/// status is the contract (CI gates on it): 0 when every finding is
/// waived or absent, non-zero otherwise, with one `path:line: [rule]`
/// line per finding.
fn cmd_lint(args: &Args) -> Result<()> {
    use crate::analysis::{lint_tree, LintConfig};
    use std::path::{Path, PathBuf};
    // Default root: the in-repo crate source, whether invoked from the
    // repo checkout (cwd) or via `cargo run` from elsewhere.
    let manifest_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let root_arg = args.get_str("root", "");
    let root: PathBuf = if !root_arg.is_empty() {
        PathBuf::from(root_arg)
    } else if Path::new("rust/src").is_dir() {
        PathBuf::from("rust/src")
    } else {
        manifest_root.join("rust/src")
    };
    let cfg_arg = args.get_str("config", "");
    let cfg = if !cfg_arg.is_empty() {
        LintConfig::load(Path::new(&cfg_arg))?
    } else if Path::new("lint.toml").is_file() {
        LintConfig::load(Path::new("lint.toml"))?
    } else if manifest_root.join("lint.toml").is_file() {
        LintConfig::load(&manifest_root.join("lint.toml"))?
    } else {
        LintConfig::empty()
    };
    let report = lint_tree(&root, &cfg)?;
    for f in &report.findings {
        // Re-anchor the relative path on the scanned root so the line
        // is clickable / feedable to an editor from wherever we ran.
        println!("{}/{}", root.display(), f);
    }
    println!(
        "lint: {} file(s) scanned, {} finding(s), {} waived",
        report.files_scanned,
        report.findings.len(),
        report.waived
    );
    if !report.findings.is_empty() {
        bail!("lint failed with {} finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!(
        "hardware threads: {} (use --threads 0 to auto-detect, --threads T to pin)",
        crate::runtime::pool::available_threads()
    );
    println!("artifact dir: {:?}", default_artifact_dir());
    match crate::runtime::Manifest::load(&default_artifact_dir()) {
        Ok(m) => {
            println!("artifacts: {} (forces dims: {:?})", m.specs.len(), m.forces_dims());
            match crate::coordinator::PjrtBackend::new(&default_artifact_dir()) {
                Ok(_) => println!("PJRT CPU client: OK"),
                Err(e) => println!("PJRT CPU client: FAILED ({e})"),
            }
        }
        Err(e) => println!("no artifacts ({e}); only --backend native|simd available"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NOTE: a bare token right after `--flag` is taken as its value,
        // so boolean flags go last (or use explicit `--flag true`).
        let a = parse(&["embed", "--alpha", "0.5", "--ld-dim", "8", "dataset.npy", "--verbose"]);
        assert_eq!(a.subcommand, "embed");
        assert_eq!(a.options["alpha"], Value::Float(0.5));
        assert_eq!(a.options["ld_dim"], Value::Int(8));
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["dataset.npy"]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["run", "--fast"]);
        assert!(a.get_flag("fast"));
    }

    #[test]
    fn getters_with_defaults() {
        let a = parse(&["x", "--n", "100"]);
        assert_eq!(a.get_usize("n", 5).unwrap(), 100);
        assert_eq!(a.get_usize("m", 5).unwrap(), 5);
        assert_eq!(a.get_f64("lr", 0.1).unwrap(), 0.1);
        assert_eq!(a.get_str("name", "d"), "d");
    }

    #[test]
    fn negative_number_value() {
        // "--shift -3" : -3 does not start with --, so it's a value.
        let a = parse(&["x", "--shift", "-3"]);
        assert_eq!(a.options["shift"], Value::Int(-3));
    }

    #[test]
    fn section_map_round_trips_into_config() {
        let a = parse(&["embed", "--alpha", "0.4"]);
        let map = a.as_section_map("embed");
        let mut cfg = crate::config::EmbedConfig::default();
        cfg.apply(&map, "embed").unwrap();
        assert_eq!(cfg.alpha, 0.4);
    }
}
