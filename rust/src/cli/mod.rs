//! Miniature CLI argument parser (clap is not available offline).
//!
//! Grammar: `funcsne <subcommand> [--key value]... [--flag]...`.
//! Keys use kebab-case on the command line and are normalised to
//! snake_case, so `--ld-dim 8` sets `ld_dim`.

use crate::config::toml_lite::{parse_value, Value};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, bare positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, Value>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let mut out = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                let key = key.replace('-', "_");
                // A following token that is not itself an option is the value;
                // otherwise this is a boolean flag.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let raw = it.next().unwrap();
                        let val = parse_value(&raw)
                            .unwrap_or(Value::Str(raw.clone()));
                        out.options.insert(key, val);
                    }
                    _ => {
                        out.options.insert(key, Value::Bool(true));
                    }
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        match self.options.get(key) {
            Some(Value::Str(s)) => s.clone(),
            Some(Value::Int(i)) => i.to_string(),
            Some(Value::Float(f)) => f.to_string(),
            Some(Value::Bool(b)) => b.to_string(),
            None => default.to_string(),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("--{key} expects a number")),
            None => Ok(default),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            Some(v) => {
                let i = v.as_i64().ok_or_else(|| anyhow::anyhow!("--{key} expects an integer"))?;
                if i < 0 {
                    bail!("--{key} expects a non-negative integer");
                }
                Ok(i as usize)
            }
            None => Ok(default),
        }
    }

    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.options.get(key), Some(Value::Bool(true)))
    }

    /// Re-express options as a `section.key` map so config `apply` works.
    pub fn as_section_map(&self, section: &str) -> BTreeMap<String, Value> {
        self.options
            .iter()
            .map(|(k, v)| (format!("{section}.{k}"), v.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // NOTE: a bare token right after `--flag` is taken as its value,
        // so boolean flags go last (or use explicit `--flag true`).
        let a = parse(&["embed", "--alpha", "0.5", "--ld-dim", "8", "dataset.npy", "--verbose"]);
        assert_eq!(a.subcommand, "embed");
        assert_eq!(a.options["alpha"], Value::Float(0.5));
        assert_eq!(a.options["ld_dim"], Value::Int(8));
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["dataset.npy"]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["run", "--fast"]);
        assert!(a.get_flag("fast"));
    }

    #[test]
    fn getters_with_defaults() {
        let a = parse(&["x", "--n", "100"]);
        assert_eq!(a.get_usize("n", 5).unwrap(), 100);
        assert_eq!(a.get_usize("m", 5).unwrap(), 5);
        assert_eq!(a.get_f64("lr", 0.1).unwrap(), 0.1);
        assert_eq!(a.get_str("name", "d"), "d");
    }

    #[test]
    fn negative_number_value() {
        // "--shift -3" : -3 does not start with --, so it's a value.
        let a = parse(&["x", "--shift", "-3"]);
        assert_eq!(a.options["shift"], Value::Int(-3));
    }

    #[test]
    fn section_map_round_trips_into_config() {
        let a = parse(&["embed", "--alpha", "0.4"]);
        let map = a.as_section_map("embed");
        let mut cfg = crate::config::EmbedConfig::default();
        cfg.apply(&map, "embed").unwrap();
        assert_eq!(cfg.alpha, 0.4);
    }
}
