//! FUnc-SNE command-line interface (the L3 leader entrypoint).
//!
//! ```text
//! funcsne embed    --dataset blobs --n 5000 --alpha 0.5 --ld-dim 2 ...
//! funcsne knn      --dataset blobs_disjoint --n 3000 --k 16
//! funcsne figure   --only fig6            # regenerate paper figures
//! funcsne hierarchy --dataset mnist --n 2000
//! funcsne info                            # backends, artifacts, dims
//! ```

use anyhow::{bail, Result};
use funcsne::cli::Args;
use funcsne::config::{EmbedConfig, KnnConfig};
use funcsne::coordinator::driver::{
    dataset_by_name, default_artifact_dir, maybe_pca_reduce, run_embedding,
};
use funcsne::data::datasets::Dataset;
use funcsne::figures::common::Scale;
use funcsne::knn::brute::brute_knn;
use funcsne::knn::nn_descent::nn_descent;
use funcsne::metrics::rnx::{rnx_curve, rnx_curve_vs_table};
use funcsne::util::{io, plot};

const HELP: &str = "\
funcsne — FUnc-SNE: flexible, fast, unconstrained neighbour embeddings

USAGE: funcsne <subcommand> [--key value]...

SUBCOMMANDS
  embed      run an embedding           --dataset NAME --n N [--alpha A]
             [--ld-dim D] [--n-iters I] [--perplexity P] [--backend native|pjrt]
             [--attraction X] [--repulsion X] [--seed S] [--out results/embed]
  knn        compare KNN finders        --dataset NAME --n N [--k K] [--iters I]
  figure     regenerate paper figures   [--only fig1..fig11|table1|table2] [--full]
  hierarchy  α-sweep hierarchy graph    --dataset NAME --n N [--ld-dim D]
  info       show artifact menu / platform

Datasets: scurve scurve_unbalanced blobs blobs_overlap blobs_disjoint coil
          mnist rat_brain tabula deep_features nested
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "embed" => cmd_embed(&args),
        "knn" => cmd_knn(&args),
        "figure" | "figures" => cmd_figure(&args),
        "hierarchy" => cmd_hierarchy(&args),
        "info" => cmd_info(),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    let name = args.get_str("dataset", "blobs");
    let n = args.get_usize("n", 2000)?;
    let seed = args.get_usize("seed", 42)? as u64;
    dataset_by_name(&name, n, seed)
}

fn cmd_embed(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let mut cfg = EmbedConfig {
        alpha: args.get_f64("alpha", 1.0)?,
        ld_dim: args.get_usize("ld_dim", 2)?,
        n_iters: args.get_usize("n_iters", 1000)?,
        seed: args.get_usize("seed", 42)? as u64,
        backend: args.get_str("backend", "native").parse()?,
        ..EmbedConfig::default()
    };
    cfg.perplexity = args.get_f64("perplexity", cfg.perplexity)?;
    cfg.attraction = args.get_f64("attraction", cfg.attraction)?;
    cfg.repulsion = args.get_f64("repulsion", cfg.repulsion)?;
    cfg.lr = args.get_f64("lr", cfg.lr)?;
    cfg.k_hd = args.get_usize("k_hd", cfg.k_hd)?.min(ds.n() - 1);
    cfg.k_ld = args.get_usize("k_ld", cfg.k_ld)?.min(ds.n() - 1);
    cfg.perplexity = cfg.perplexity.min(cfg.k_hd as f64);
    cfg.validate()?;
    let x = maybe_pca_reduce(ds.x.clone(), 64, cfg.seed);
    println!(
        "embedding {} (n={}, d={} → {}), α={}, backend {:?}",
        ds.name,
        ds.n(),
        ds.d(),
        cfg.ld_dim,
        cfg.alpha,
        cfg.backend
    );
    let report = run_embedding(x, &cfg, &default_artifact_dir())?;
    let y = report.engine.embedding();
    println!(
        "done in {:.2}s ({:.1} iters/s, {} HD refreshes, {} σ recalibrations)",
        report.seconds,
        report.iters_per_sec,
        report.engine.stats.hd_refines,
        report.engine.stats.recalibrated_points
    );
    if ds.n() <= 4000 {
        let c = rnx_curve(&ds.x, y, 50.min(ds.n() - 2));
        println!("R_NX AUC = {:.3}", c.auc);
    }
    if cfg.ld_dim == 2 {
        println!(
            "{}",
            plot::scatter_2d("embedding", y.data(), &ds.labels, ds.n(), 78, 22)
        );
    }
    let out = args.get_str("out", "results/embed");
    io::write_npy_f32(
        std::path::Path::new(&format!("{out}.npy")),
        y.data(),
        &[y.n(), y.d()],
    )?;
    println!("wrote {out}.npy");
    Ok(())
}

fn cmd_knn(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let k = args.get_usize("k", 16)?;
    let iters = args.get_usize("iters", 300)?;
    println!("exact ground truth (n={}, k={k})...", ds.n());
    let truth = brute_knn(&ds.x, k);
    println!("NN-descent...");
    let nnd = nn_descent(&ds.x, &KnnConfig { k, rho: 0.8, ..KnnConfig::default() });
    let c1 = rnx_curve_vs_table(&truth, &nnd.table, k);
    println!("proposed iterative finder ({iters} engine iterations)...");
    let mut cfg = funcsne::figures::common::figure_config(ds.n(), 2, 1.0);
    cfg.k_hd = k.max(8);
    cfg.refine_base_prob = 1.0;
    let mut engine = funcsne::engine::FuncSne::new(ds.x.clone(), cfg)?;
    let mut backend = funcsne::ld::NativeBackend::new();
    engine.run(iters, &mut backend)?;
    let c2 = rnx_curve_vs_table(&truth, &engine.knn.hd, k);
    println!(
        "R_NX AUC: nn-descent {:.3} ({} dist evals) | proposed {:.3}",
        c1.auc, nnd.dist_evals, c2.auc
    );
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let scale = if args.get_flag("full") { Scale::Full } else { Scale::from_env() };
    let only = args.get_str("only", "all");
    type Driver = fn(Scale) -> Result<String>;
    let all: Vec<(&str, Driver)> = vec![
        ("fig1", funcsne::figures::fig1::run),
        ("fig2", funcsne::figures::fig2::run),
        ("fig3", funcsne::figures::fig3::run),
        ("fig4", funcsne::figures::fig4::run),
        ("fig5", funcsne::figures::fig5::run),
        ("fig6", funcsne::figures::fig6::run),
        ("fig7", funcsne::figures::fig7::run),
        ("fig8", funcsne::figures::fig8::run),
        ("fig9_10", funcsne::figures::fig9_10::run),
        ("fig11", funcsne::figures::fig11::run),
        ("table1", funcsne::figures::table1::run),
        ("table2", funcsne::figures::table2::run),
    ];
    let mut ran = 0;
    for (name, f) in all {
        if only == "all" || only == name {
            println!(">>> {name}");
            f(scale)?;
            ran += 1;
        }
    }
    if ran == 0 {
        bail!("no figure matched {only:?}");
    }
    Ok(())
}

fn cmd_hierarchy(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let ld_dim = args.get_usize("ld_dim", 4)?;
    let mut cfg = funcsne::figures::common::figure_config(ds.n(), ld_dim, 1.0);
    cfg.n_iters = 0;
    let mut engine = funcsne::engine::FuncSne::new(ds.x.clone(), cfg)?;
    let mut backend = funcsne::ld::NativeBackend::new();
    let sweep = funcsne::cluster::hierarchy::SweepConfig {
        iters_per_level: args.get_usize("iters_per_level", 300)?,
        ..Default::default()
    };
    let graph = funcsne::cluster::hierarchy::alpha_sweep(&mut engine, &mut backend, &sweep)?;
    let pos = funcsne::cluster::layout::layout(&graph, 250, 1);
    println!(
        "{}",
        funcsne::cluster::layout::render_ascii(&graph, &pos, 70, 20)
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("artifact dir: {:?}", default_artifact_dir());
    match funcsne::runtime::Manifest::load(&default_artifact_dir()) {
        Ok(m) => {
            println!("artifacts: {} (forces dims: {:?})", m.specs.len(), m.forces_dims());
            match funcsne::coordinator::PjrtBackend::new(&default_artifact_dir()) {
                Ok(_) => println!("PJRT CPU client: OK"),
                Err(e) => println!("PJRT CPU client: FAILED ({e})"),
            }
        }
        Err(e) => println!("no artifacts ({e}); only --backend native available"),
    }
    Ok(())
}
