//! FUnc-SNE command-line entrypoint (the L3 leader binary).
//!
//! ```text
//! funcsne embed    --dataset blobs --n 5000 --alpha 0.5 --ld-dim 2 ...
//! funcsne knn      --dataset blobs_disjoint --n 3000 --k 16
//! funcsne figure   --only fig6            # regenerate paper figures
//! funcsne hierarchy --dataset mnist --n 2000
//! funcsne serve    --addr 127.0.0.1:7878  # HTTP/JSON embedding service
//! funcsne info                            # backends, artifacts, dims
//! ```
//!
//! All subcommand logic lives in [`funcsne::cli`], which runs on the
//! session facade ([`funcsne::session`]).

use anyhow::Result;
use funcsne::cli::{self, Args};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    cli::run(&args)
}
