//! High-dimensional side: perplexity calibration and sparse affinities.

pub mod perplexity;
pub mod affinity;

pub use affinity::Affinities;
