//! Per-point Gaussian bandwidth (σ_i) calibration to a target perplexity.
//!
//! t-SNE (Eq. 1) requires the conditional distribution
//! `p_{j|i} ∝ exp(-δ_ij² / 2σ_i²)` over point i's neighbours to have a
//! user-set perplexity `2^{H(P_i)}`. σ_i is found by bisection on
//! β_i = 1/(2σ_i²). FUnc-SNE recalibrates continuously as neighbour sets
//! improve, so the solver supports **warm restarts** from the previous β
//! (the paper's "warm restart from their previous value, for efficiency").

/// Result of one calibration.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Precision β = 1/(2σ²).
    pub beta: f32,
    /// Achieved perplexity.
    pub perplexity: f32,
    /// Bisection iterations used (telemetry for the warm-start tests).
    pub iters: u32,
}

/// Entropy (nats) and normaliser of p ∝ exp(-β d²) over `sq_dists`.
///
/// Returns (H, sum_p) where H is the Shannon entropy in nats of the
/// normalised distribution. Distances are *squared*.
fn entropy(sq_dists: &[f32], beta: f32) -> (f64, f64) {
    // Subtract the min for numerical stability (shifts cancel in p).
    let dmin = sq_dists.iter().copied().fold(f32::INFINITY, f32::min);
    let mut sum_p = 0.0f64;
    let mut sum_dp = 0.0f64;
    for &d in sq_dists {
        let e = (-(beta as f64) * ((d - dmin) as f64)).exp();
        sum_p += e;
        sum_dp += (d - dmin) as f64 * e;
    }
    if sum_p <= 0.0 {
        return (0.0, 0.0);
    }
    // H = log Z + β <d²>
    let h = sum_p.ln() + (beta as f64) * sum_dp / sum_p;
    (h, sum_p)
}

/// Calibrate β for one point.
///
/// `sq_dists` — squared distances to the point's current neighbour set;
/// `target_perplexity` — clamped to at most `len(sq_dists)` implicitly
/// (entropy of a k-point distribution is ≤ ln k);
/// `warm_beta` — previous β to restart from (None → 1.0).
pub fn calibrate(sq_dists: &[f32], target_perplexity: f64, warm_beta: Option<f32>) -> Calibration {
    debug_assert!(!sq_dists.is_empty());
    let target_h = target_perplexity.max(1.0001).ln().min((sq_dists.len() as f64).ln());
    let mut beta = warm_beta.unwrap_or(1.0).max(1e-12);
    let (mut lo, mut hi) = (0.0f64, f64::INFINITY);
    let mut iters = 0u32;
    let mut h = entropy(sq_dists, beta).0;
    // Bracket: entropy decreases with β.
    while iters < 64 && (h - target_h).abs() > 1e-5 {
        if h > target_h {
            lo = beta as f64;
            beta = if hi.is_finite() { ((lo + hi) / 2.0) as f32 } else { beta * 2.0 };
        } else {
            hi = beta as f64;
            beta = ((lo + hi) / 2.0) as f32;
        }
        h = entropy(sq_dists, beta).0;
        iters += 1;
    }
    Calibration { beta, perplexity: h.exp() as f32, iters }
}

/// Normalised conditionals p_{j|i} for the point's neighbour distances
/// at precision β (written into `out`, aligned with `sq_dists`).
pub fn conditionals(sq_dists: &[f32], beta: f32, out: &mut [f32]) {
    debug_assert_eq!(sq_dists.len(), out.len());
    let dmin = sq_dists.iter().copied().fold(f32::INFINITY, f32::min);
    let mut sum = 0.0f64;
    for (o, &d) in out.iter_mut().zip(sq_dists) {
        let e = (-(beta as f64) * ((d - dmin) as f64)).exp();
        *o = e as f32;
        sum += e;
    }
    let inv = if sum > 0.0 { (1.0 / sum) as f32 } else { 0.0 };
    for o in out.iter_mut() {
        *o *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    #[test]
    fn achieves_target_perplexity() {
        pt::check("perplexity-hit", 48, |rng, _| {
            let k = rng.range_usize(8, 64);
            let target = rng.range_f64(2.0, (k as f64 * 0.8).max(2.1));
            let dists: Vec<f32> = (0..k).map(|_| rng.f32() * 10.0 + 0.01).collect();
            let cal = calibrate(&dists, target, None);
            crate::prop_assert!(
                (cal.perplexity as f64 - target).abs() < 0.05 * target,
                "target {target} achieved {}",
                cal.perplexity
            );
            Ok(())
        });
    }

    #[test]
    fn warm_restart_is_cheaper() {
        // Scale distances so the correct β is far from the cold-start 1.0
        // (the realistic regime: σ_i reflects the data scale).
        let mut rng = crate::util::Rng::new(1);
        let dists: Vec<f32> = (0..32).map(|_| (rng.f32() * 5.0 + 0.1) * 60.0).collect();
        let cold = calibrate(&dists, 20.0, None);
        // Perturb distances slightly — the refinement scenario.
        let dists2: Vec<f32> = dists.iter().map(|&d| d * 1.02).collect();
        let warm = calibrate(&dists2, 20.0, Some(cold.beta));
        let cold2 = calibrate(&dists2, 20.0, None);
        assert!(
            warm.iters < cold2.iters,
            "warm {} vs cold {} iterations",
            warm.iters,
            cold2.iters
        );
        assert!((warm.perplexity - cold2.perplexity).abs() < 0.5);
    }

    #[test]
    fn conditionals_sum_to_one_and_order() {
        let dists = vec![0.5f32, 1.0, 4.0, 9.0];
        let cal = calibrate(&dists, 3.0, None);
        let mut p = vec![0.0f32; 4];
        conditionals(&dists, cal.beta, &mut p);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        // Closer neighbours get more mass.
        assert!(p[0] >= p[1] && p[1] >= p[2] && p[2] >= p[3]);
    }

    #[test]
    fn degenerate_equal_distances() {
        let dists = vec![2.0f32; 16];
        let cal = calibrate(&dists, 8.0, None);
        let mut p = vec![0.0f32; 16];
        conditionals(&dists, cal.beta, &mut p);
        for &pi in &p {
            assert!((pi - 1.0 / 16.0).abs() < 1e-5);
        }
        assert!(cal.perplexity > 15.0); // uniform => perplexity = k
    }

    #[test]
    fn perplexity_clamped_by_k() {
        // target 50 with only 8 neighbours: best achievable is 8.
        let dists = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let cal = calibrate(&dists, 50.0, None);
        assert!(cal.perplexity <= 8.1);
        assert!(cal.perplexity > 6.0);
    }
}
