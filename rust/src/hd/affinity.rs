//! Sparse HD affinities p_{j|i} aligned with the estimated neighbour
//! table.
//!
//! FUnc-SNE never materialises the full symmetric P matrix. Instead each
//! *directed* edge (i → slot s holding j) carries the conditional
//! p_{j|i}; the force pass applies each directed edge's attraction to
//! both endpoints, which reproduces the symmetrised
//! p_ij = (p_{j|i}+p_{i|j})/2N sum exactly (each unordered pair is
//! visited once per direction).
//!
//! Calibration is *incremental*: only points flagged dirty (they
//! received a new HD neighbour, or the user changed perplexity / metric
//! on the fly) are recalibrated, with warm-started β, matching §3 of the
//! paper.

use super::perplexity::{calibrate, conditionals};
use crate::knn::iterative::IterativeKnn;
use crate::knn::NeighborTable;

/// Per-edge conditionals + per-point calibration state.
#[derive(Clone, Debug)]
pub struct Affinities {
    k: usize,
    /// p_{j|i}, aligned with the HD table's slot layout (n·k).
    p: Vec<f32>,
    /// Calibrated precision β_i = 1/(2σ_i²) per point.
    pub beta: Vec<f32>,
    /// Achieved perplexity per point (telemetry).
    pub achieved: Vec<f32>,
}

impl Affinities {
    pub fn new(n: usize, k: usize) -> Self {
        Affinities {
            k,
            p: vec![0.0; n * k],
            beta: vec![1.0; n],
            achieved: vec![0.0; n],
        }
    }

    pub fn n(&self) -> usize {
        self.beta.len()
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The full slot-conditional array (n·k), for serialization.
    pub fn p_all(&self) -> &[f32] {
        &self.p
    }

    /// Rebuild from serialized parts, validating shape consistency.
    pub fn from_raw(
        k: usize,
        p: Vec<f32>,
        beta: Vec<f32>,
        achieved: Vec<f32>,
    ) -> Result<Affinities, String> {
        if k == 0 {
            return Err("affinities: k must be >= 1".to_string());
        }
        if achieved.len() != beta.len() || p.len() != beta.len() * k {
            return Err(format!(
                "affinities: shape mismatch (k {k}, p {}, beta {}, achieved {})",
                p.len(),
                beta.len(),
                achieved.len()
            ));
        }
        Ok(Affinities { k, p, beta, achieved })
    }

    /// p_{j|i} for the HD table's slot `s` of point `i`.
    #[inline(always)]
    pub fn p_slot(&self, i: usize, s: usize) -> f32 {
        self.p[i * self.k + s]
    }

    /// Slice of all slot conditionals for point `i`.
    #[inline(always)]
    pub fn p_row(&self, i: usize) -> &[f32] {
        &self.p[i * self.k..(i + 1) * self.k]
    }

    /// Recalibrate a single point from its current HD neighbour slots.
    pub fn recalibrate_point(&mut self, i: usize, hd: &NeighborTable, perplexity: f64) {
        let len = hd.len(i);
        if len == 0 {
            for s in 0..self.k {
                self.p[i * self.k + s] = 0.0;
            }
            return;
        }
        let mut sq = [0.0f32; 256];
        debug_assert!(len <= 256);
        for (s, (_, d)) in hd.entries(i).enumerate() {
            sq[s] = d;
        }
        let cal = calibrate(&sq[..len], perplexity, Some(self.beta[i]));
        self.beta[i] = cal.beta;
        self.achieved[i] = cal.perplexity;
        let row = &mut self.p[i * self.k..i * self.k + len];
        conditionals(&sq[..len], cal.beta, row);
        for s in len..self.k {
            self.p[i * self.k + s] = 0.0;
        }
    }

    /// Recalibrate every dirty point, clearing flags. Returns how many
    /// points were recalibrated.
    pub fn recalibrate_dirty(&mut self, knn: &mut IterativeKnn, perplexity: f64) -> usize {
        let mut count = 0;
        for i in 0..knn.n() {
            if knn.hd_dirty[i] {
                self.recalibrate_point(i, &knn.hd, perplexity);
                knn.hd_dirty[i] = false;
                count += 1;
            }
        }
        count
    }

    /// Recalibrate all points unconditionally (perplexity / metric change).
    pub fn recalibrate_all(&mut self, knn: &mut IterativeKnn, perplexity: f64) {
        for i in 0..knn.n() {
            self.recalibrate_point(i, &knn.hd, perplexity);
            knn.hd_dirty[i] = false;
        }
    }

    /// Dynamic insertion bookkeeping.
    pub fn push_point(&mut self) {
        self.p.extend(std::iter::repeat(0.0).take(self.k));
        self.beta.push(1.0);
        self.achieved.push(0.0);
    }

    /// swap-remove bookkeeping mirroring the neighbour tables.
    pub fn swap_remove_point(&mut self, gone: usize) {
        let last = self.n() - 1;
        if gone != last {
            for s in 0..self.k {
                self.p.swap(gone * self.k + s, last * self.k + s);
            }
            self.beta.swap(gone, last);
            self.achieved.swap(gone, last);
        }
        self.p.truncate(last * self.k);
        self.beta.pop();
        self.achieved.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::knn::brute::brute_knn;
    use crate::util::Rng;

    fn setup(n: usize, k: usize, seed: u64) -> (crate::data::Matrix, IterativeKnn) {
        let ds = datasets::blobs(n, 6, 3, 0.8, 8.0, seed);
        let exact = brute_knn(&ds.x, k);
        let mut knn = IterativeKnn::new(n, k, k);
        // Install exact sets so calibration quality is isolated from KNN.
        for i in 0..n {
            for (j, d) in exact.entries(i) {
                knn.hd.insert(i, j, d);
            }
        }
        (ds.x, knn)
    }

    #[test]
    fn conditionals_normalised_after_recalibration() {
        let (_, mut knn) = setup(200, 16, 1);
        let mut aff = Affinities::new(200, 16);
        aff.recalibrate_all(&mut knn, 10.0);
        for i in 0..200 {
            let sum: f32 = aff.p_row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {i} sums to {sum}");
            assert!((aff.achieved[i] - 10.0).abs() < 0.5, "perp {}", aff.achieved[i]);
        }
    }

    #[test]
    fn dirty_flags_drive_incremental_recalibration() {
        let (x, mut knn) = setup(100, 12, 2);
        let mut aff = Affinities::new(100, 12);
        aff.recalibrate_all(&mut knn, 8.0);
        assert_eq!(aff.recalibrate_dirty(&mut knn, 8.0), 0);
        // Dirty two points; only they should be recalibrated.
        knn.hd_dirty[3] = true;
        knn.hd_dirty[7] = true;
        let _ = x;
        assert_eq!(aff.recalibrate_dirty(&mut knn, 8.0), 2);
        assert!(!knn.hd_dirty[3] && !knn.hd_dirty[7]);
    }

    #[test]
    fn closer_neighbours_get_more_mass() {
        let (_, mut knn) = setup(80, 10, 3);
        let mut aff = Affinities::new(80, 10);
        aff.recalibrate_all(&mut knn, 5.0);
        for i in 0..80 {
            // max-p slot should be the min-distance slot
            let dists: Vec<f32> = knn.hd.entries(i).map(|(_, d)| d).collect();
            let ps = aff.p_row(i);
            let amin = dists
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            let amax = ps[..dists.len()]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(amin, amax, "point {i}");
        }
    }

    #[test]
    fn dynamic_bookkeeping() {
        let (_, mut knn) = setup(50, 8, 4);
        let mut aff = Affinities::new(50, 8);
        aff.recalibrate_all(&mut knn, 5.0);
        aff.push_point();
        assert_eq!(aff.n(), 51);
        let beta_last = aff.beta[49];
        aff.swap_remove_point(10);
        assert_eq!(aff.n(), 50);
        // old last-but-one (index 49 pre-push was data; after push last=50
        // empty). After removing 10, old index 50's beta moved to 10.
        assert_eq!(aff.beta[10], 1.0);
        let _ = beta_last;
    }

    #[test]
    fn empty_point_zeroes_row() {
        let knn = IterativeKnn::new(3, 4, 4);
        let mut aff = Affinities::new(3, 4);
        aff.recalibrate_point(0, &knn.hd, 5.0);
        assert!(aff.p_row(0).iter().all(|&p| p == 0.0));
    }
}
