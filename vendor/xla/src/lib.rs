//! Offline **stub** of the `xla` PJRT binding.
//!
//! The real crate links the PJRT C API and executes AOT-compiled HLO.
//! This stub presents the same API surface used by
//! `rust/src/runtime/pjrt.rs` but [`PjRtClient::cpu`] always fails, so
//! every PJRT code path degrades to the same graceful fallback as a
//! missing `artifacts/` directory (the CLI and examples then use the
//! native backend). Swap this path dependency for the real vendored
//! binding to enable the hot path; no call-site changes are needed.
//!
//! Types that can only be produced *through* a client (executables,
//! buffers, computations) are uninhabited enums: the methods on them
//! typecheck but are statically unreachable in the stub.

/// Error type mirroring the binding's debug-printable error.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "xla stub: PJRT is unavailable in this offline build — swap vendor/xla \
         for the real binding (or use --backend native)"
            .to_string(),
    )
}

/// Element types accepted by [`Literal::create_from_shape_and_untyped_data`].
#[derive(Clone, Copy, Debug)]
pub enum ElementType {
    F32,
}

/// Host literal. Uninhabited in the stub (creation always fails).
pub enum Literal {}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        match self {}
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        match self {}
    }

    pub fn copy_raw_to(&self, _out: &mut [f32]) -> Result<()> {
        match *self {}
    }
}

/// Parsed HLO module. Uninhabited in the stub (parsing always fails).
pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// A computation ready for compilation.
pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match *proto {}
    }
}

/// Device buffer returned by execution.
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

/// Compiled executable.
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    /// In the real binding this opens the CPU PJRT plugin; the stub
    /// always reports unavailability.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match *computation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("unavailable"));
    }

    #[test]
    fn literal_creation_fails_gracefully() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0; 16])
            .is_err());
    }
}
