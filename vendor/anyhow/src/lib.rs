//! A minimal, dependency-free, API-compatible subset of the `anyhow`
//! crate, vendored because the build environment is fully offline.
//!
//! Provides the surface this workspace actually uses: [`Error`],
//! [`Result`], the [`anyhow!`], [`bail!`] and [`ensure!`] macros, and
//! the [`Context`] extension trait over `Result` and `Option`.
//!
//! Like the real `anyhow::Error`, [`Error`] deliberately does *not*
//! implement `std::error::Error`; that is what lets the blanket
//! `From<E: std::error::Error>` conversion coexist with the reflexive
//! `From<Error> for Error` the `?` operator needs.

use std::fmt::{self, Display};

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with an optional chain of causes.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.cause.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.cause.is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, msg) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut out = Error::msg(e.to_string());
        // Flatten the std error's source chain into ours.
        let mut causes = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        let mut tail: Option<Box<Error>> = None;
        for msg in causes.into_iter().rev() {
            tail = Some(Box::new(Error { msg, cause: tail }));
        }
        out.cause = tail;
        out
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"));
        r?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_chains() {
        let e = io_fail().context("writing report").unwrap_err();
        assert_eq!(e.to_string(), "writing report");
        assert!(e.root_cause().contains("disk on fire"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(12).unwrap_err().to_string().contains("x too big: 12"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("condition failed"));
    }
}
